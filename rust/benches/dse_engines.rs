//! E11 — simulation-engine comparison on the DSE scoring hot path: a
//! sharded sweep ([`ptmc::shard::ShardedSweep`]) scores a cache-module
//! grid, a DMA grid, a DRAM/DMA timing grid, and a full joint cross
//! product under the legacy lockstep core, the event-driven batched
//! core, and the one-pass cores — the cache grid classifier
//! (`ptmc::engine::grid`), the vectorized timing core
//! (`ptmc::engine::timing`), and the hierarchical joint sweep core
//! (`ptmc::engine::sweep`) — all on the same prepared traces.
//!
//! The event core wins over lockstep three ways (compressed traces,
//! concurrent shard replay, memoized remap — see PR 2).  The grid core
//! wins over event structurally on the cache module: one classification
//! pass scores all `(num_lines, assoc)` candidates simultaneously
//! (Mattson inclusion), each candidate then replaying only its miss
//! stream (PR 3).  The timing core wins the same way on the DRAM/DMA
//! module sweep (PR 4): the cache candidate is fixed across that sweep,
//! so one classification + op-queue extraction per shard feeds a single
//! multi-lane walk that times every DRAM/DMA candidate at once — the
//! hit-dominated cache loop runs once instead of once per candidate.
//! The joint core composes both (PR 5): a cache x DRAM x DMA cross
//! product classifies per line width, extracts per cache candidate,
//! and walks each cache's lane set once — per-candidate event replay
//! pays the full trace per joint point instead.  Scores are asserted
//! bit-identical across all cores (including equal best points); only
//! wall-clock differs.  Targets: grid >= 5x over event on the
//! cache-module sweep, timing core >= 4x over event on the DRAM/DMA
//! sweep, joint core >= 5x over event on the joint sweep.
//!
//! The bench also runs `explore` under the coordinate and joint search
//! strategies on a single-module (cache-only) space — where coordinate
//! descent is itself exhaustive, so the two must agree exactly — and
//! asserts equal best score and equal best configuration.
//!
//! A memory-technology sweep (PR 6) scores the same sharded workload
//! across DDR4, HBM2, and optical SRAM through the `MemoryDevice`
//! trait, asserting the DDR4 instance reproduces the legacy base-path
//! score bit for bit.
//!
//! Emits `bench_results/dse_engines.csv`,
//! `bench_results/engine_speedup.json`, and a repo-root `BENCH_dse.json`
//! so the bench trajectory is machine-readable across PRs.

use std::path::PathBuf;
use std::time::Instant;

use ptmc::bench::{fmt_cycles, fmt_speedup, json_section, sized, smoke, upsert_json_section, Table};
use ptmc::controller::{CacheConfig, ControllerConfig, DmaConfig};
use ptmc::dram::RowPolicy;
use ptmc::dse::{explore, explore_with, Evaluator, Grids, SearchOptions, SearchStrategy};
use ptmc::engine::EngineKind;
use ptmc::fpga::Device;
use ptmc::mem::MemTech;
use ptmc::shard::ShardedSweep;
use ptmc::tensor::synth::{generate, Profile, SynthConfig};

/// The cache-module grid (§5.3 module 1 shape): line width fixed,
/// capacity x associativity swept — 16 candidates.
fn cache_grid(elem_bytes: usize) -> (ControllerConfig, Vec<CacheConfig>) {
    let base = ControllerConfig::default_for(elem_bytes);
    let mut grid = Vec::new();
    for &num_lines in &[256usize, 1024, 4096, 16384] {
        for &assoc in &[1usize, 2, 4, 8] {
            grid.push(CacheConfig {
                line_bytes: 64,
                num_lines,
                assoc,
                hit_latency: base.cache.hit_latency,
            });
        }
    }
    (base, grid)
}

/// The DMA-module grid — 6 candidates (scored per candidate under all
/// engines; the grid core specializes the cache module only).
fn dma_grid(elem_bytes: usize) -> Vec<ControllerConfig> {
    let mut grid = Vec::new();
    for &num_dmas in &[1usize, 2, 4] {
        for &buffer_bytes in &[1024usize, 8192] {
            let mut cfg = ControllerConfig::default_for(elem_bytes);
            cfg.dma = DmaConfig {
                num_dmas,
                buffers_per_dma: 2,
                buffer_bytes,
                setup_cycles: 8,
            };
            grid.push(cfg);
        }
    }
    grid
}

/// The DRAM/DMA timing grid (the PR 4 sweep): the base cache module is
/// fixed while 3 DRAM timing variants (channels x row policy) cross 9
/// DMA shapes — 27 candidates, 3 distinct remap-memo keys.
fn timing_grid(elem_bytes: usize) -> Vec<ControllerConfig> {
    let mut grid = Vec::new();
    for &(channels, row_policy) in &[
        (1usize, RowPolicy::Open),
        (4, RowPolicy::Open),
        (4, RowPolicy::Closed),
    ] {
        for &num_dmas in &[1usize, 2, 4] {
            for &buffer_bytes in &[1024usize, 4096, 16384] {
                let mut cfg = ControllerConfig::default_for(elem_bytes);
                {
                    let dram = cfg.mem.ddr4_mut();
                    dram.channels = channels;
                    dram.row_policy = row_policy;
                }
                cfg.dma.num_dmas = num_dmas;
                cfg.dma.buffer_bytes = buffer_bytes;
                grid.push(cfg);
            }
        }
    }
    grid
}

/// The joint cross-product grid (the PR 5 sweep): cache geometry x
/// DRAM timing x DMA shape all free at once — 72 joint candidates over
/// 8 distinct caches spanning 2 line widths, so every level of the
/// hierarchical core (classify per width, extract per cache, one walk
/// per lane set) is exercised.
fn joint_grid(elem_bytes: usize) -> Vec<ControllerConfig> {
    let mut grid = Vec::new();
    for &line_bytes in &[32usize, 64] {
        for &num_lines in &[1024usize, 4096] {
            for &assoc in &[2usize, 4] {
                for &(channels, row_policy) in &[
                    (1usize, RowPolicy::Open),
                    (4, RowPolicy::Open),
                    (4, RowPolicy::Closed),
                ] {
                    for &(num_dmas, buffer_bytes) in
                        &[(1usize, 1024usize), (2, 4096), (4, 16384)]
                    {
                        let mut cfg = ControllerConfig::default_for(elem_bytes);
                        cfg.cache.line_bytes = line_bytes;
                        cfg.cache.num_lines = num_lines;
                        cfg.cache.assoc = assoc;
                        {
                            let dram = cfg.mem.ddr4_mut();
                            dram.channels = channels;
                            dram.row_policy = row_policy;
                        }
                        cfg.dma.num_dmas = num_dmas;
                        cfg.dma.buffer_bytes = buffer_bytes;
                        grid.push(cfg);
                    }
                }
            }
        }
    }
    grid
}

/// Walk up from the current directory to the repo root (the directory
/// holding ROADMAP.md) so BENCH_dse.json lands in one canonical place
/// regardless of where cargo runs the bench binary.
fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            return std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        }
    }
}

fn main() {
    let rank = 16usize;
    let workers = 4usize;
    let nnz = sized(300_000, 10_000);
    println!("generating {nnz}-nnz zipf tensor...");
    let t = generate(&SynthConfig {
        dims: vec![
            sized(30_000, 3_000),
            sized(20_000, 2_000),
            sized(12_000, 1_200),
        ],
        nnz,
        profile: Profile::Zipf { alpha_milli: 1250 },
        seed: 2026,
    });
    let (base, caches) = cache_grid(t.record_bytes());
    let dmas = dma_grid(t.record_bytes());
    let cache_cfgs: Vec<ControllerConfig> = caches
        .iter()
        .map(|cc| {
            let mut cfg = base.clone();
            cfg.cache = *cc;
            cfg
        })
        .collect();

    let timing_count = timing_grid(t.record_bytes()).len();
    let joint_count = joint_grid(t.record_bytes()).len();
    println!(
        "preparing {workers}-worker sweeps \
         ({} cache + {} DMA + {} DRAM/DMA + {} joint candidates)...",
        caches.len(),
        dmas.len(),
        timing_count,
        joint_count,
    );

    // Warm allocator and page cache once on a scratch sweep, asserting
    // bit-identity before any timing means anything.  Every *timed*
    // path below then runs on its own freshly prepared sweep so each
    // engine pays its own remap-memo warm-up inside its clock (the
    // PR 2 methodology): lockstep re-simulates remap per candidate by
    // design, event and grid each warm the memo once per mode.
    {
        let scratch = ShardedSweep::prepare(&t, rank, workers);
        let warm_lockstep = scratch.makespan_with(&base, EngineKind::Lockstep);
        let warm_event = scratch.makespan_with(&base, EngineKind::Event);
        assert_eq!(
            warm_lockstep, warm_event,
            "engines must be bit-identical before timing means anything"
        );
    }

    // --- Cache-module sweep: the grid core's home turf. ---
    let (cache_lockstep, cache_lockstep_wall, dma_lockstep, dma_lockstep_wall) = {
        let sweep = ShardedSweep::prepare(&t, rank, workers);
        let t0 = Instant::now();
        let cache: Vec<u64> = cache_cfgs
            .iter()
            .map(|cfg| sweep.makespan_with(cfg, EngineKind::Lockstep))
            .collect();
        let cache_wall = t0.elapsed();
        let t1 = Instant::now();
        let dma: Vec<u64> = dmas
            .iter()
            .map(|cfg| sweep.makespan_with(cfg, EngineKind::Lockstep))
            .collect();
        (cache, cache_wall, dma, t1.elapsed())
    };

    let (cache_event, cache_event_wall, dma_event, dma_event_wall) = {
        let sweep = ShardedSweep::prepare(&t, rank, workers);
        let t0 = Instant::now();
        let cache: Vec<u64> = cache_cfgs
            .iter()
            .map(|cfg| sweep.makespan_with(cfg, EngineKind::Event))
            .collect();
        let cache_wall = t0.elapsed();
        let t1 = Instant::now();
        let dma: Vec<u64> = dmas
            .iter()
            .map(|cfg| sweep.makespan_with(cfg, EngineKind::Event))
            .collect();
        (cache, cache_wall, dma, t1.elapsed())
    };

    let (cache_grid_scores, cache_grid_wall) = {
        let sweep = ShardedSweep::prepare(&t, rank, workers);
        let t2 = Instant::now();
        (sweep.makespans_for_cache_grid(&base, &caches), t2.elapsed())
    };

    // --- DRAM/DMA timing sweep: the vectorized timing core's home
    // turf (PR 4).  Each side gets a fresh sweep so it pays its own
    // remap-memo warm-up inside its clock.
    let timing_cfgs = timing_grid(t.record_bytes());
    let (timing_event_scores, timing_event_wall) = {
        let sweep = ShardedSweep::prepare(&t, rank, workers);
        let t0 = Instant::now();
        let scores: Vec<u64> = timing_cfgs
            .iter()
            .map(|cfg| sweep.makespan_with(cfg, EngineKind::Event))
            .collect();
        (scores, t0.elapsed())
    };
    let (timing_core_scores, timing_core_wall) = {
        let sweep = ShardedSweep::prepare(&t, rank, workers);
        let t0 = Instant::now();
        (
            sweep.makespans_for_timing_grid(&base, &timing_cfgs),
            t0.elapsed(),
        )
    };

    assert_eq!(
        timing_event_scores, timing_core_scores,
        "DRAM/DMA-sweep scores must be bit-identical (event vs timing core)"
    );
    let timing_best = (0..timing_event_scores.len())
        .min_by_key(|&i| timing_event_scores[i])
        .unwrap();
    let timing_best_core = (0..timing_core_scores.len())
        .min_by_key(|&i| timing_core_scores[i])
        .unwrap();
    assert_eq!(
        timing_best, timing_best_core,
        "timing core and event must select the same best DRAM/DMA configuration"
    );

    // --- Joint cross-product sweep: the hierarchical sweep core's
    // home turf (PR 5).  The event side pays a full per-candidate
    // replay per joint point; the joint core classifies per line
    // width, extracts per cache, and walks each cache's DRAM/DMA lane
    // set once.  Each side gets a fresh sweep so it pays its own
    // remap-memo warm-up inside its clock.
    let joint_cfgs = joint_grid(t.record_bytes());
    println!("joint sweep: {} candidates...", joint_cfgs.len());
    let (joint_event_scores, joint_event_wall) = {
        let sweep = ShardedSweep::prepare(&t, rank, workers);
        let t0 = Instant::now();
        let scores: Vec<u64> = joint_cfgs
            .iter()
            .map(|cfg| sweep.makespan_with(cfg, EngineKind::Event))
            .collect();
        (scores, t0.elapsed())
    };
    let (joint_core_scores, joint_core_wall) = {
        let sweep = ShardedSweep::prepare(&t, rank, workers);
        let t0 = Instant::now();
        (sweep.makespans_for_joint_grid(&joint_cfgs), t0.elapsed())
    };
    assert_eq!(
        joint_event_scores, joint_core_scores,
        "joint-sweep scores must be bit-identical (event vs joint core)"
    );
    let joint_best = (0..joint_event_scores.len())
        .min_by_key(|&i| joint_event_scores[i])
        .unwrap();
    let joint_best_core = (0..joint_core_scores.len())
        .min_by_key(|&i| joint_core_scores[i])
        .unwrap();
    assert_eq!(
        joint_best, joint_best_core,
        "joint core and event must select the same best joint configuration"
    );

    // --- Memory-technology sweep (PR 6): the same sharded workload
    // scored across DDR4, HBM2, and optical SRAM through the
    // `MemoryDevice` trait.  DDR4's `default_config()` is exactly the
    // pre-refactor base configuration, so its score must reproduce the
    // legacy base-path makespan bit for bit.
    let mem_techs = [MemTech::Ddr4, MemTech::Hbm2, MemTech::Osram];
    let (mem_tech_scores, mem_tech_legacy, mem_tech_wall) = {
        let sweep = ShardedSweep::prepare(&t, rank, workers);
        let t0 = Instant::now();
        let scores: Vec<u64> = mem_techs
            .iter()
            .map(|&tech| {
                let mut cfg = base.clone();
                cfg.mem = tech.default_config();
                sweep.makespan_with(&cfg, EngineKind::Event)
            })
            .collect();
        let wall = t0.elapsed();
        let legacy = sweep.makespan_with(&base, EngineKind::Event);
        (scores, legacy, wall)
    };
    if mem_tech_scores[0] != mem_tech_legacy {
        let msg = format!(
            "DDR4 through the memory-tech axis scored {} but the legacy \
             base path scored {}",
            mem_tech_scores[0], mem_tech_legacy
        );
        assert!(std::env::var_os("PTMC_BENCH_ENFORCE").is_none(), "{msg}");
        println!("WARNING: {msg}");
    } else {
        println!("mem-tech DDR4 score == legacy base-path score. OK");
    }

    // --- Search-strategy agreement: on a single-module (cache-only)
    // space coordinate descent is itself exhaustive, so `explore` under
    // the coordinate and joint strategies must agree exactly — same
    // best score, same best configuration.
    {
        let sweep = ShardedSweep::prepare_with_engine(&t, rank, workers, EngineKind::Grid);
        let eval = Evaluator::ShardedSim { sweep: &sweep };
        let dev = Device::alveo_u250();
        let base_cfg = ControllerConfig::default_for(t.record_bytes());
        let base_dram = base_cfg.mem.ddr4().expect("default base is DDR4").clone();
        let cache_only = Grids {
            cache_line_bytes: vec![32, 64],
            cache_num_lines: vec![1024, 4096],
            cache_assoc: vec![2, 4],
            dma_num: vec![base_cfg.dma.num_dmas],
            dma_buffers: vec![base_cfg.dma.buffers_per_dma],
            dma_buffer_bytes: vec![base_cfg.dma.buffer_bytes],
            dram_channels: vec![base_dram.channels],
            dram_banks: vec![base_dram.banks],
            dram_row_policy: vec![base_dram.row_policy],
            remap_max_pointers: vec![base_cfg.remapper.max_pointers],
            mem_techs: vec![MemTech::Ddr4],
        };
        let ex_coord = explore(&base_cfg, &cache_only, &dev, &eval);
        let ex_joint = explore_with(
            &base_cfg,
            &cache_only,
            &dev,
            &eval,
            &SearchOptions {
                strategy: SearchStrategy::Joint,
                top_k: 3,
                resume: false,
                checkpoint_every: 0,
            },
        );
        assert_eq!(
            ex_joint.best.cycles, ex_coord.best.cycles,
            "joint and coordinate must agree on a single-module space"
        );
        assert_eq!(
            ex_joint.best.cfg, ex_coord.best.cfg,
            "joint and coordinate must pick the same configuration"
        );
        println!(
            "explore agreement: coordinate == joint on the cache-only space \
             ({:.3e} cycles). OK",
            ex_joint.best.cycles
        );
    }

    assert_eq!(
        cache_lockstep, cache_event,
        "cache-module scores must be bit-identical (lockstep vs event)"
    );
    assert_eq!(
        cache_event, cache_grid_scores,
        "cache-module scores must be bit-identical (event vs grid)"
    );
    let best_idx = (0..cache_event.len())
        .min_by_key(|&i| cache_event[i])
        .unwrap();
    let best_idx_grid = (0..cache_grid_scores.len())
        .min_by_key(|&i| cache_grid_scores[i])
        .unwrap();
    assert_eq!(
        best_idx, best_idx_grid,
        "grid and event must select the same best cache configuration"
    );

    assert_eq!(
        dma_lockstep, dma_event,
        "DMA-module scores must be bit-identical"
    );

    let event_speedup =
        (cache_lockstep_wall + dma_lockstep_wall).as_secs_f64()
            / (cache_event_wall + dma_event_wall).as_secs_f64();
    let grid_speedup = cache_event_wall.as_secs_f64() / cache_grid_wall.as_secs_f64();
    let timing_speedup = timing_event_wall.as_secs_f64() / timing_core_wall.as_secs_f64();
    let joint_speedup = joint_event_wall.as_secs_f64() / joint_core_wall.as_secs_f64();

    let mut tbl = Table::new(&["sweep", "engine", "configs", "wall ms", "speedup", "best cycles"]);
    let ms = |d: std::time::Duration| format!("{:.0}", d.as_secs_f64() * 1e3);
    let best_cache = *cache_event.iter().min().unwrap();
    tbl.row(&[
        "cache".into(),
        "lockstep".into(),
        caches.len().to_string(),
        ms(cache_lockstep_wall),
        fmt_speedup(cache_lockstep_wall.as_secs_f64() / cache_lockstep_wall.as_secs_f64()),
        fmt_cycles(best_cache),
    ]);
    tbl.row(&[
        "cache".into(),
        "event".into(),
        caches.len().to_string(),
        ms(cache_event_wall),
        fmt_speedup(cache_lockstep_wall.as_secs_f64() / cache_event_wall.as_secs_f64()),
        fmt_cycles(best_cache),
    ]);
    tbl.row(&[
        "cache".into(),
        "grid (one-pass)".into(),
        caches.len().to_string(),
        ms(cache_grid_wall),
        fmt_speedup(cache_lockstep_wall.as_secs_f64() / cache_grid_wall.as_secs_f64()),
        fmt_cycles(best_cache),
    ]);
    let best_dma = *dma_event.iter().min().unwrap();
    tbl.row(&[
        "dma".into(),
        "lockstep".into(),
        dmas.len().to_string(),
        ms(dma_lockstep_wall),
        "1.00x".into(),
        fmt_cycles(best_dma),
    ]);
    tbl.row(&[
        "dma".into(),
        "event".into(),
        dmas.len().to_string(),
        ms(dma_event_wall),
        fmt_speedup(dma_lockstep_wall.as_secs_f64() / dma_event_wall.as_secs_f64()),
        fmt_cycles(best_dma),
    ]);
    let best_timing = *timing_event_scores.iter().min().unwrap();
    tbl.row(&[
        "dram+dma".into(),
        "event".into(),
        timing_cfgs.len().to_string(),
        ms(timing_event_wall),
        "1.00x".into(),
        fmt_cycles(best_timing),
    ]);
    tbl.row(&[
        "dram+dma".into(),
        "timing (one-walk)".into(),
        timing_cfgs.len().to_string(),
        ms(timing_core_wall),
        fmt_speedup(timing_speedup),
        fmt_cycles(best_timing),
    ]);
    let best_joint = *joint_event_scores.iter().min().unwrap();
    tbl.row(&[
        "joint".into(),
        "event".into(),
        joint_cfgs.len().to_string(),
        ms(joint_event_wall),
        "1.00x".into(),
        fmt_cycles(best_joint),
    ]);
    tbl.row(&[
        "joint".into(),
        "sweep (hierarchical)".into(),
        joint_cfgs.len().to_string(),
        ms(joint_core_wall),
        fmt_speedup(joint_speedup),
        fmt_cycles(best_joint),
    ]);
    for (tech, &score) in mem_techs.iter().zip(&mem_tech_scores) {
        tbl.row(&[
            "mem_tech".into(),
            format!("event ({tech})"),
            "1".into(),
            ms(mem_tech_wall),
            format!("{} mW", tech.default_config().power_proxy_mw()),
            fmt_cycles(score),
        ]);
    }
    tbl.emit(
        "E11 — DSE sweep scoring: lockstep vs event vs one-pass grid/timing cores \
         vs hierarchical joint core (identical scores)",
        Some(std::path::Path::new("bench_results/dse_engines.csv")),
    );

    // Machine-readable trajectory: legacy engine_speedup.json line plus
    // the richer repo-root BENCH_dse.json.
    let per_candidate: Vec<String> = cache_event.iter().map(|c| c.to_string()).collect();
    let json = format!(
        "{{\"bench\":\"dse_engines\",\"nnz\":{nnz},\"workers\":{workers},\
         \"configs\":{},\"lockstep_ms\":{:.1},\"event_ms\":{:.1},\
         \"speedup\":{event_speedup:.2}}}\n",
        caches.len() + dmas.len(),
        (cache_lockstep_wall + dma_lockstep_wall).as_secs_f64() * 1e3,
        (cache_event_wall + dma_event_wall).as_secs_f64() * 1e3,
    );
    let bench_json = format!(
        "{{\n  \"bench\": \"dse_engines\",\n  \"pr\": 6,\n  \"nnz\": {nnz},\n  \
         \"workers\": {workers},\n  \"rank\": {rank},\n  \"smoke\": {},\n  \
         \"cache_sweep\": {{\n    \"configs\": {},\n    \
         \"lockstep_ms\": {:.1},\n    \"event_ms\": {:.1},\n    \
         \"grid_ms\": {:.1},\n    \"grid_vs_event_speedup\": {grid_speedup:.2},\n    \
         \"best_index\": {best_idx},\n    \"per_candidate_cycles\": [{}]\n  }},\n  \
         \"dma_sweep\": {{\n    \"configs\": {},\n    \"lockstep_ms\": {:.1},\n    \
         \"event_ms\": {:.1}\n  }},\n  \
         \"timing_sweep\": {{\n    \"configs\": {},\n    \"event_ms\": {:.1},\n    \
         \"timing_core_ms\": {:.1},\n    \
         \"timing_vs_event_speedup\": {timing_speedup:.2},\n    \
         \"best_index\": {timing_best},\n    \"per_candidate_cycles\": [{}]\n  }},\n  \
         \"joint_sweep\": {{\n    \"configs\": {},\n    \"event_ms\": {:.1},\n    \
         \"joint_core_ms\": {:.1},\n    \
         \"joint_vs_event_speedup\": {joint_speedup:.2},\n    \
         \"best_index\": {joint_best},\n    \
         \"explore_joint_equals_coordinate_on_separable_space\": true,\n    \
         \"per_candidate_cycles\": [{}]\n  }},\n  \
         \"mem_tech\": {{\n    \"techs\": [{}],\n    \"cycles\": [{}],\n    \
         \"power_proxy_mw\": [{}],\n    \"event_ms\": {:.1},\n    \
         \"ddr4_matches_legacy_path\": {}\n  }},\n  \
         \"event_vs_lockstep_speedup\": {event_speedup:.2}\n}}\n",
        smoke(),
        caches.len(),
        cache_lockstep_wall.as_secs_f64() * 1e3,
        cache_event_wall.as_secs_f64() * 1e3,
        cache_grid_wall.as_secs_f64() * 1e3,
        per_candidate.join(", "),
        dmas.len(),
        dma_lockstep_wall.as_secs_f64() * 1e3,
        dma_event_wall.as_secs_f64() * 1e3,
        timing_cfgs.len(),
        timing_event_wall.as_secs_f64() * 1e3,
        timing_core_wall.as_secs_f64() * 1e3,
        timing_event_scores
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(", "),
        joint_cfgs.len(),
        joint_event_wall.as_secs_f64() * 1e3,
        joint_core_wall.as_secs_f64() * 1e3,
        joint_event_scores
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(", "),
        mem_techs
            .iter()
            .map(|tech| format!("\"{tech}\""))
            .collect::<Vec<_>>()
            .join(", "),
        mem_tech_scores
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(", "),
        mem_techs
            .iter()
            .map(|tech| tech.default_config().power_proxy_mw().to_string())
            .collect::<Vec<_>>()
            .join(", "),
        mem_tech_wall.as_secs_f64() * 1e3,
        mem_tech_scores[0] == mem_tech_legacy,
    );
    let _ = std::fs::create_dir_all("bench_results");
    if let Err(e) = std::fs::write("bench_results/engine_speedup.json", &json) {
        eprintln!("warning: failed to write engine_speedup.json: {e}");
    }
    let bench_path = repo_root().join("BENCH_dse.json");
    // This bench rebuilds the trajectory file wholesale; carry over the
    // sections the streaming_scale and classify_kernel benches own.
    let mut bench_json = bench_json;
    if let Ok(old) = std::fs::read_to_string(&bench_path) {
        for key in ["streaming", "classify_kernel"] {
            if let Some(section) = json_section(&old, key) {
                bench_json = upsert_json_section(&bench_json, key, &section);
            }
        }
    }
    if let Err(e) = std::fs::write(&bench_path, &bench_json) {
        eprintln!("warning: failed to write {}: {e}", bench_path.display());
    } else {
        println!("[bench trajectory written to {}]", bench_path.display());
    }
    print!("{json}");
    println!(
        "cache sweep: grid {grid_speedup:.2}x over event; \
         dram+dma sweep: timing core {timing_speedup:.2}x over event; \
         joint sweep: hierarchical core {joint_speedup:.2}x over event; \
         full sweep: event {event_speedup:.2}x over lockstep"
    );

    if !smoke() {
        // The PR 3/4 acceptance claims.  Wall-clock ratios are host
        // noise on loaded or low-core machines, so a shortfall warns
        // by default and only fails under PTMC_BENCH_ENFORCE=1 (set it
        // for acceptance runs on a quiet multi-core host).
        if grid_speedup < 5.0 {
            let msg =
                format!("grid core below the 5x cache-sweep target: {grid_speedup:.2}x over event");
            assert!(
                std::env::var_os("PTMC_BENCH_ENFORCE").is_none(),
                "{msg}"
            );
            println!("WARNING: {msg}");
        } else {
            println!("grid core >= 5x cache-sweep target met ({grid_speedup:.2}x). OK");
        }
        if timing_speedup < 4.0 {
            let msg = format!(
                "timing core below the 4x DRAM/DMA-sweep target: \
                 {timing_speedup:.2}x over event"
            );
            assert!(
                std::env::var_os("PTMC_BENCH_ENFORCE").is_none(),
                "{msg}"
            );
            println!("WARNING: {msg}");
        } else {
            println!(
                "timing core >= 4x DRAM/DMA-sweep target met ({timing_speedup:.2}x). OK"
            );
        }
        if joint_speedup < 5.0 {
            let msg = format!(
                "joint core below the 5x joint-sweep target: \
                 {joint_speedup:.2}x over event"
            );
            assert!(
                std::env::var_os("PTMC_BENCH_ENFORCE").is_none(),
                "{msg}"
            );
            println!("WARNING: {msg}");
        } else {
            println!("joint core >= 5x joint-sweep target met ({joint_speedup:.2}x). OK");
        }
        if event_speedup < 3.0 {
            println!(
                "WARNING: event engine below the 3x target on this host ({event_speedup:.2}x)"
            );
        } else {
            println!("event engine >= 3x target met ({event_speedup:.2}x). OK");
        }
    }
}
