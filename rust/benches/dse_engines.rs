//! E11 — simulation-engine comparison on the DSE scoring hot path: a
//! sharded sweep ([`ptmc::shard::ShardedSweep`]) scores a grid of
//! controller candidates under the legacy lockstep core and under the
//! event-driven batched core, on the same prepared traces.
//!
//! The event core wins three ways, all structural: (1) delta-encoded
//! compressed traces stream ~6x less trace data per replay, (2) the K
//! per-shard replays run on concurrent host threads (independent fresh
//! controller instances), and (3) the sequential remap pass — identical
//! for every candidate sharing (DRAM, remapper) knobs, i.e. the whole
//! cache/DMA grid — is memoized instead of re-simulated per candidate.
//! Scores are asserted bit-identical; only wall-clock differs.  Target:
//! >= 3x on the candidate-scoring loop.
//!
//! Emits `bench_results/dse_engines.csv` and a machine-readable
//! `bench_results/engine_speedup.json` line for the bench trajectory.

use std::time::Instant;

use ptmc::bench::{fmt_cycles, fmt_speedup, sized, smoke, Table};
use ptmc::controller::{CacheConfig, ControllerConfig, DmaConfig};
use ptmc::engine::EngineKind;
use ptmc::shard::ShardedSweep;
use ptmc::tensor::synth::{generate, Profile, SynthConfig};

/// The candidate grid: a cache sweep plus a DMA sweep, holding the
/// remapper fixed — exactly the per-module DSE shape (§5.3).
fn grid(elem_bytes: usize) -> Vec<ControllerConfig> {
    let mut grid = Vec::new();
    for &num_lines in &[256usize, 1024, 4096, 16384] {
        for &assoc in &[2usize, 4] {
            let mut cfg = ControllerConfig::default_for(elem_bytes);
            cfg.cache = CacheConfig {
                line_bytes: 64,
                num_lines,
                assoc,
                hit_latency: 2,
            };
            grid.push(cfg);
        }
    }
    for &num_dmas in &[1usize, 2, 4] {
        for &buffer_bytes in &[1024usize, 8192] {
            let mut cfg = ControllerConfig::default_for(elem_bytes);
            cfg.dma = DmaConfig {
                num_dmas,
                buffers_per_dma: 2,
                buffer_bytes,
                setup_cycles: 8,
            };
            grid.push(cfg);
        }
    }
    grid
}

fn main() {
    let rank = 16usize;
    let workers = 4usize;
    let nnz = sized(300_000, 10_000);
    println!("generating {nnz}-nnz zipf tensor...");
    let t = generate(&SynthConfig {
        dims: vec![
            sized(30_000, 3_000),
            sized(20_000, 2_000),
            sized(12_000, 1_200),
        ],
        nnz,
        profile: Profile::Zipf { alpha_milli: 1250 },
        seed: 2026,
    });
    let grid = grid(t.record_bytes());

    println!(
        "preparing {workers}-worker sweep ({} candidate configs)...",
        grid.len()
    );
    let sweep = ShardedSweep::prepare(&t, rank, workers);

    // Warm both paths once (allocator, page cache) outside the clock.
    let warm_cfg = ControllerConfig::default_for(t.record_bytes());
    let warm_lockstep = sweep.makespan_with(&warm_cfg, EngineKind::Lockstep);
    let warm_event = sweep.makespan_with(&warm_cfg, EngineKind::Event);
    assert_eq!(
        warm_lockstep, warm_event,
        "engines must be bit-identical before timing means anything"
    );

    // Fresh sweep for the timed event run so the remap memo starts
    // cold and its warm-up is charged to the event side fairly.
    let timed_sweep = ShardedSweep::prepare(&t, rank, workers);

    let t0 = Instant::now();
    let lockstep_scores: Vec<u64> = grid
        .iter()
        .map(|cfg| timed_sweep.makespan_with(cfg, EngineKind::Lockstep))
        .collect();
    let lockstep_wall = t0.elapsed();

    let t1 = Instant::now();
    let event_scores: Vec<u64> = grid
        .iter()
        .map(|cfg| timed_sweep.makespan_with(cfg, EngineKind::Event))
        .collect();
    let event_wall = t1.elapsed();

    assert_eq!(
        lockstep_scores, event_scores,
        "per-candidate scores must be bit-identical"
    );

    let mut tbl = Table::new(&["engine", "configs", "wall ms", "speedup", "best cycles"]);
    let best = *lockstep_scores.iter().min().unwrap();
    let speedup = lockstep_wall.as_secs_f64() / event_wall.as_secs_f64();
    tbl.row(&[
        "lockstep (legacy)".into(),
        grid.len().to_string(),
        format!("{:.0}", lockstep_wall.as_secs_f64() * 1e3),
        "1.00x".into(),
        fmt_cycles(best),
    ]);
    tbl.row(&[
        "event (batched)".into(),
        grid.len().to_string(),
        format!("{:.0}", event_wall.as_secs_f64() * 1e3),
        fmt_speedup(speedup),
        fmt_cycles(*event_scores.iter().min().unwrap()),
    ]);
    tbl.emit(
        "E11 — DSE sweep scoring: lockstep vs event engine (identical scores)",
        Some(std::path::Path::new("bench_results/dse_engines.csv")),
    );

    let json = format!(
        "{{\"bench\":\"dse_engines\",\"nnz\":{nnz},\"workers\":{workers},\
         \"configs\":{},\"lockstep_ms\":{:.1},\"event_ms\":{:.1},\
         \"speedup\":{speedup:.2}}}\n",
        grid.len(),
        lockstep_wall.as_secs_f64() * 1e3,
        event_wall.as_secs_f64() * 1e3,
    );
    let _ = std::fs::create_dir_all("bench_results");
    if let Err(e) = std::fs::write("bench_results/engine_speedup.json", &json) {
        eprintln!("warning: failed to write engine_speedup.json: {e}");
    }
    print!("{json}");

    if !smoke() {
        if speedup < 3.0 {
            println!("WARNING: event engine below the 3x target on this host ({speedup:.2}x)");
        } else {
            println!("event engine >= 3x target met ({speedup:.2}x). OK");
        }
    }
}
