//! PR-7 out-of-core scaling bench: prove the bounded-memory pipeline
//! at the paper's FROSTT scale (Table 2 tops out at 144M nnz).
//!
//! Full mode synthesizes a 100M-nnz Zipf tensor through the dedup-free
//! streamed generator, plans shards from the one-pass coordinate
//! histogram, runs one CP-ALS iteration (`decompose`), and a
//! coordinate DSE (`explore`, analytic PMS evaluator) — asserting the
//! process's peak RSS stays under the 4 GiB budget the CLI's
//! `--memory-budget 4g` would enforce.  Smoke mode (`PTMC_BENCH_SMOKE`)
//! shrinks to 2M nnz; the RSS assertion still runs (trivially) so the
//! CI job exercises the same code path.
//!
//! Emits a `streaming` section (ingest nnz/s, peak RSS) into the
//! repo-root `BENCH_dse.json`, preserving the sections the dse_engines
//! bench owns.

use std::path::PathBuf;
use std::time::Instant;

use ptmc::bench::{sized, smoke, upsert_json_file};
use ptmc::controller::ControllerConfig;
use ptmc::cpd::{cp_als, AlsConfig, NativeBackend};
use ptmc::dse::{explore_with, EvaluatorBuilder, Grids, SearchOptions, SearchStrategy};
use ptmc::fpga::Device;
use ptmc::mem::MemTech;
use ptmc::pms::TensorProfile;
use ptmc::shard::CoordHistogram;
use ptmc::tensor::frostt::DEFAULT_BLOCK_NNZ;
use ptmc::tensor::synth::{generate_streamed, Profile, SynthConfig};
use ptmc::tensor::Coord;
use ptmc::util::{format_size, peak_rss_bytes};

/// The acceptance budget: 4 GiB peak RSS for the full 100M-nnz run.
const BUDGET_BYTES: u64 = 4 << 30;

fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            return std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        }
    }
}

fn main() {
    let nnz = sized(100_000_000, 2_000_000);
    let rank = 16usize;
    println!(
        "streaming scale: {nnz} nnz, rank {rank}, budget {}",
        format_size(BUDGET_BYTES)
    );

    // Phase 1: dedup-free streamed synthesis (the 100M-nnz ingest).
    let t0 = Instant::now();
    let mut t = generate_streamed(&SynthConfig {
        dims: vec![1_000_000, 1_000_000, 1_000_000],
        nnz,
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed: 7,
    });
    let synth_s = t0.elapsed().as_secs_f64();
    let nnz_per_s = nnz as f64 / synth_s;
    println!("  synthesize: {synth_s:.1}s ({nnz_per_s:.3e} nnz/s)");

    // Phase 2: shard planning from the one-pass histogram sketch, fed
    // in ingestion-sized blocks as streamed parsing would.
    let t0 = Instant::now();
    let mut hist = CoordHistogram::new();
    let mut at = 0;
    while at < t.nnz() {
        let hi = (at + DEFAULT_BLOCK_NNZ).min(t.nnz());
        let cols: Vec<Vec<Coord>> = (0..t.n_modes())
            .map(|m| t.mode_col(m)[at..hi].to_vec())
            .collect();
        hist.observe(&cols);
        at = hi;
    }
    for mode in 0..t.n_modes() {
        let plan = hist.plan_for_dim(mode, t.dims()[mode], 4);
        println!(
            "  shard plan mode {mode}: imbalance {:.3} over 4 shards",
            plan.imbalance()
        );
    }
    println!("  shard planning: {:.1}s", t0.elapsed().as_secs_f64());

    // Phase 3: one CP-ALS iteration (the `decompose` acceptance leg).
    let t0 = Instant::now();
    let als = AlsConfig {
        rank,
        max_iters: 1,
        tol: 0.0,
        ..AlsConfig::default()
    };
    let model = cp_als(&mut t, &als, &mut NativeBackend);
    let decompose_s = t0.elapsed().as_secs_f64();
    println!(
        "  decompose (1 iter, native): {decompose_s:.1}s, fit {:.4}",
        model.final_fit()
    );

    // Phase 4: coordinate DSE over the analytic PMS evaluator (the
    // `explore` acceptance leg; profile measurement is the O(nnz) part).
    let t0 = Instant::now();
    let profile = TensorProfile::measure(&t);
    let base = ControllerConfig::default_for(t.record_bytes());
    let dev = Device::alveo_u250();
    let eval = EvaluatorBuilder::new()
        .rank(rank)
        .memory_budget(Some(BUDGET_BYTES))
        .pms(&profile);
    let grids = Grids {
        mem_techs: vec![MemTech::Ddr4],
        ..Grids::default()
    };
    let opts = SearchOptions {
        strategy: SearchStrategy::Coordinate,
        top_k: 1,
        resume: false,
        checkpoint_every: 0,
    };
    let ex = explore_with(&base, &grids, &dev, &eval, &opts);
    let explore_s = t0.elapsed().as_secs_f64();
    println!(
        "  explore (coordinate, pms): {explore_s:.1}s, {} configs visited",
        ex.visited.len()
    );

    // The acceptance assertion: the whole pipeline stayed under budget.
    let peak = peak_rss_bytes();
    match peak {
        Some(p) => {
            println!("  peak RSS: {} (budget {})", format_size(p), format_size(BUDGET_BYTES));
            assert!(
                p <= BUDGET_BYTES,
                "peak RSS {} exceeded the {} out-of-core budget",
                format_size(p),
                format_size(BUDGET_BYTES)
            );
        }
        None => println!("  peak RSS: unavailable on this platform (budget not checked)"),
    }

    let section = format!(
        "{{\n    \"pr\": 7,\n    \"smoke\": {},\n    \"nnz\": {nnz},\n    \
         \"rank\": {rank},\n    \"synth_s\": {synth_s:.1},\n    \
         \"synth_nnz_per_s\": {nnz_per_s:.3e},\n    \
         \"decompose_iters\": 1,\n    \"decompose_s\": {decompose_s:.1},\n    \
         \"explore_s\": {explore_s:.1},\n    \"explore_configs\": {},\n    \
         \"budget_bytes\": {BUDGET_BYTES},\n    \"peak_rss_bytes\": {},\n    \
         \"within_budget\": {}\n  }}",
        smoke(),
        ex.visited.len(),
        peak.map_or_else(|| "null".to_string(), |p| p.to_string()),
        peak.map_or(true, |p| p <= BUDGET_BYTES),
    );
    let bench_path = repo_root().join("BENCH_dse.json");
    if let Err(e) = upsert_json_file(&bench_path, "streaming", &section) {
        eprintln!("warning: failed to update {}: {e}", bench_path.display());
    } else {
        println!("[streaming section written to {}]", bench_path.display());
    }
}
