//! E6 — DMA Engine design-space sweep (§5.2.1/§5.3): streaming
//! throughput vs number of DMAs, buffers per DMA, and buffer size on the
//! remap-phase traffic (the DMA-heaviest phase), plus the on-chip buffer
//! cost of each point.

use ptmc::bench::{fmt_cycles, sized, smoke, Table};
use ptmc::controller::{ControllerConfig, DmaConfig, MemLayout, MemoryController};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};

fn main() {
    let t = generate(&SynthConfig {
        dims: vec![sized(8_000, 800), sized(5_000, 500), sized(3_000, 300)],
        nnz: sized(150_000, 10_000),
        profile: Profile::Zipf { alpha_milli: 1250 },
        seed: 17,
    });
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 16);

    // The measured workload: the DMA Engine's own duty cycle — streaming
    // the sorted tensor in (one pass per mode, as Approach 1 does).
    let run = |dma: DmaConfig| -> (u64, usize) {
        let mut cfg = ControllerConfig::default_for(t.record_bytes());
        cfg.dma = dma;
        let onchip = cfg.dma.buffer_capacity_bytes();
        let mut ctl = MemoryController::new(cfg);
        let bytes = t.nnz() * t.record_bytes();
        for mode in 0..t.n_modes() {
            let base = layout.tensor_base[mode % 2];
            let mut off = 0usize;
            while off < bytes {
                let chunk = 16_384.min(bytes - off);
                ctl.request(ptmc::controller::Access::Stream {
                    addr: base + off as u64,
                    bytes: chunk,
                });
                off += chunk;
            }
        }
        (ctl.now(), onchip)
    };

    // --- Sweep: buffer size x buffers per DMA (1 DMA) ---
    let mut tbl = Table::new(&["num_dmas", "buffers", "buffer bytes", "cycles", "on-chip bytes"]);
    let mut best: (u64, DmaConfig) = (u64::MAX, DmaConfig::default_2x4k());
    for &num_dmas in &[1usize, 2, 4] {
        for &buffers_per_dma in &[1usize, 2, 4] {
            for &buffer_bytes in &[512usize, 2048, 8192, 32768] {
                let dma = DmaConfig {
                    num_dmas,
                    buffers_per_dma,
                    buffer_bytes,
                    setup_cycles: 8,
                };
                let (cycles, onchip) = run(dma);
                if cycles < best.0 {
                    best = (cycles, dma);
                }
                tbl.row(&[
                    num_dmas.to_string(),
                    buffers_per_dma.to_string(),
                    buffer_bytes.to_string(),
                    fmt_cycles(cycles),
                    onchip.to_string(),
                ]);
            }
        }
    }
    tbl.emit(
        "E6 — DMA parameter sweep on remap + streaming re-read",
        Some(std::path::Path::new("bench_results/dse_dma.csv")),
    );

    // Shape checks.  (1) a single tiny buffer exposes the per-chunk
    // setup and must be strictly worst; (2) setup can be amortized
    // either by outstanding buffers (>= 2 in flight) or by large
    // buffers — the best point must do at least one of these; (3) the
    // cheapest near-best point should use double buffering with small
    // buffers rather than one huge buffer (the SRAM-efficiency lesson).
    let (worst_cycles, _) = run(DmaConfig {
        num_dmas: 1,
        buffers_per_dma: 1,
        buffer_bytes: 512,
        setup_cycles: 8,
    });
    if !smoke() {
        assert!(
            worst_cycles > best.0,
            "1x1x512B should not be optimal ({worst_cycles} vs {})",
            best.0
        );
        assert!(
            best.1.num_dmas * best.1.buffers_per_dma >= 2 || best.1.buffer_bytes >= 8192,
            "best must amortize setup: {:?}",
            best.1
        );
    }
    // Find the minimum on-chip cost achieving within 0.5% of best.
    let mut cheapest: Option<(usize, DmaConfig)> = None;
    for &num_dmas in &[1usize, 2, 4] {
        for &buffers_per_dma in &[1usize, 2, 4] {
            for &buffer_bytes in &[512usize, 2048, 8192, 32768] {
                let dma = DmaConfig {
                    num_dmas,
                    buffers_per_dma,
                    buffer_bytes,
                    setup_cycles: 8,
                };
                let (c, onchip) = run(dma);
                let improves = match cheapest {
                    None => true,
                    Some((b, _)) => onchip < b,
                };
                if c as f64 <= best.0 as f64 * 1.005 && improves {
                    cheapest = Some((onchip, dma));
                }
            }
        }
    }
    let (onchip, dma) = cheapest.unwrap();
    if !smoke() {
        assert!(
            dma.buffers_per_dma >= 2,
            "SRAM-cheapest near-best point should double-buffer: {dma:?}"
        );
    }
    println!(
        "best: {} DMAs x {} buffers x {} B -> {} cycles ({:.2}x over worst)",
        best.1.num_dmas,
        best.1.buffers_per_dma,
        best.1.buffer_bytes,
        best.0,
        worst_cycles as f64 / best.0 as f64
    );
    println!(
        "cheapest within 0.5% of best: {} x {} x {} B ({} on-chip bytes) — \
         double buffering buys big-buffer speed at a fraction of the SRAM",
        dma.num_dmas, dma.buffers_per_dma, dma.buffer_bytes, onchip
    );
}
