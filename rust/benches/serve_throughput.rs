//! PR-10 service bench: sustained queries/second of the DSE batch
//! server at several worker-pool widths, cold vs repeat, with memo
//! hit-rate stats.
//!
//! Per worker count the bench boots a fresh in-process server (fresh
//! memo), pipelines one batch of *distinct*-tensor jobs (cold: every
//! candidate simulates), then re-submits the identical batch (repeat:
//! every candidate must be a cross-query memo hit — zero new
//! simulations, byte-identical frontiers).  The headline claim is the
//! repeat batch completing >= 3x faster than the cold one; shortfalls
//! warn by default and only fail under `PTMC_BENCH_ENFORCE=1`.
//! `PTMC_BENCH_SMOKE` shrinks the workload and sweeps one pool width.
//!
//! Emits a `serve_throughput` section into the repo-root
//! `BENCH_dse.json` (preserving sections owned by other bench
//! binaries).

use std::path::PathBuf;
use std::time::Instant;

use ptmc::dse::SearchStrategy;
use ptmc::engine::EngineKind;
use ptmc::serve::client;
use ptmc::serve::proto::{EvalKind, GridPreset, JobSpec};
use ptmc::serve::{ServeConfig, Server};
use ptmc::tensor::synth::Profile;

use ptmc::bench::{sized, smoke, upsert_json_file};

/// Walk up to the repo root (the directory holding ROADMAP.md) so
/// BENCH_dse.json lands in one canonical place.
fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            return std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        }
    }
}

/// Warn by default; fail hard when `PTMC_BENCH_ENFORCE=1` is set.
fn warn_or_enforce(msg: &str) {
    assert!(std::env::var_os("PTMC_BENCH_ENFORCE").is_none(), "{msg}");
    eprintln!("warning: {msg}");
}

/// One exploration job; distinct `seed`s give distinct tensors (and
/// so distinct memo contexts), identical seeds repeat a context.
fn job(id: u64, seed: u64, nnz: usize) -> JobSpec {
    JobSpec {
        id,
        tenant: "bench".to_string(),
        dims: vec![256, 192, 128],
        nnz,
        seed,
        profile: Profile::Zipf { alpha_milli: 1200 },
        rank: 8,
        evaluator: EvalKind::Sim,
        engine: EngineKind::Event,
        strategy: SearchStrategy::Coordinate,
        top_k: 1,
        grid: GridPreset::Smoke,
    }
}

struct Round {
    workers: usize,
    cold_qps: f64,
    repeat_qps: f64,
    speedup: f64,
    repeat_hit_rate_pct: f64,
}

fn round(workers: usize, n_jobs: usize, nnz: usize) -> Round {
    let server = Server::bind(
        "127.0.0.1:0",
        ServeConfig {
            workers,
            ..ServeConfig::default()
        },
    )
    .expect("bind serve socket");
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run());

    let jobs: Vec<JobSpec> = (0..n_jobs)
        .map(|i| job(i as u64 + 1, 1000 + i as u64, nnz))
        .collect();

    let t0 = Instant::now();
    let cold = client::submit_batch(&addr, &jobs).expect("cold batch");
    let cold_s = t0.elapsed().as_secs_f64();
    assert!(
        cold.errors.is_empty(),
        "cold batch failed: {:?}",
        cold.errors
    );

    let t1 = Instant::now();
    let rep = client::submit_batch(&addr, &jobs).expect("repeat batch");
    let rep_s = t1.elapsed().as_secs_f64();
    assert!(rep.errors.is_empty(), "repeat batch failed: {:?}", rep.errors);

    // The repeat batch must be pure memo: zero new simulations, and
    // frontiers byte-identical to the cold run's.
    assert_eq!(
        rep.memo_misses(),
        0,
        "repeat batch performed new simulations"
    );
    assert!(rep.memo_hits() > 0, "repeat batch reported no memo hits");
    for (a, b) in cold.results.iter().zip(&rep.results) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.best.cycles_bits, b.best.cycles_bits);
        assert_eq!(a.pareto, b.pareto, "repeat frontier diverged (job {})", a.id);
    }

    client::shutdown(&addr).expect("shutdown");
    handle.join().expect("server thread").expect("server run");

    let hits = rep.memo_hits() as f64;
    let total = hits + rep.memo_misses() as f64;
    Round {
        workers,
        cold_qps: n_jobs as f64 / cold_s,
        repeat_qps: n_jobs as f64 / rep_s,
        speedup: cold_s / rep_s,
        repeat_hit_rate_pct: hits * 100.0 / total,
    }
}

fn main() {
    let worker_counts: &[usize] = if smoke() { &[4] } else { &[4, 8, 16] };
    let n_jobs = sized(8, 4);
    let nnz = sized(60_000, 5_000);

    println!("serve throughput: {n_jobs} jobs/batch, {nnz} nnz, smoke grid");
    let mut rounds = Vec::new();
    for &w in worker_counts {
        let r = round(w, n_jobs, nnz);
        println!(
            "  {} workers: cold {:.2} q/s, repeat {:.2} q/s -> {:.1}x \
             (repeat hit rate {:.1}%)",
            r.workers, r.cold_qps, r.repeat_qps, r.speedup, r.repeat_hit_rate_pct
        );
        rounds.push(r);
    }

    let fmt_list = |f: &dyn Fn(&Round) -> String| -> String {
        rounds.iter().map(|r| f(r)).collect::<Vec<_>>().join(", ")
    };
    let section = format!(
        "{{\n    \"pr\": 10,\n    \"smoke\": {},\n    \"jobs_per_batch\": {n_jobs},\n    \
         \"nnz\": {nnz},\n    \"workers\": [{}],\n    \"cold_qps\": [{}],\n    \
         \"repeat_qps\": [{}],\n    \"repeat_speedup\": [{}],\n    \
         \"repeat_hit_rate_pct\": [{}],\n    \"target_repeat_speedup\": 3.0\n  }}",
        smoke(),
        fmt_list(&|r| r.workers.to_string()),
        fmt_list(&|r| format!("{:.2}", r.cold_qps)),
        fmt_list(&|r| format!("{:.2}", r.repeat_qps)),
        fmt_list(&|r| format!("{:.2}", r.speedup)),
        fmt_list(&|r| format!("{:.1}", r.repeat_hit_rate_pct)),
    );
    let bench_path = repo_root().join("BENCH_dse.json");
    match upsert_json_file(&bench_path, "serve_throughput", &section) {
        Err(e) => eprintln!("warning: failed to update {}: {e}", bench_path.display()),
        Ok(()) => println!("[bench section written to {}]", bench_path.display()),
    }

    // The acceptance claim.  Wall-clock ratios are host noise on
    // loaded machines, so shortfalls warn by default and only fail
    // under PTMC_BENCH_ENFORCE=1; smoke workloads are too small for a
    // stable ratio, so smoke only checks the memo invariants above.
    if !smoke() {
        for r in &rounds {
            if r.speedup < 3.0 {
                warn_or_enforce(&format!(
                    "repeat batch below 3x at {} workers: {:.2}x",
                    r.workers, r.speedup
                ));
            }
        }
    }
}
