//! E3 — regenerate paper Table 2 (characteristics of FROSTT tensors) for
//! the scaled synthetic suite, checking each metric lands in the paper's
//! range once the ~1/1000 scale factor is applied.

use ptmc::bench::Table;
use ptmc::tensor::stats::characteristics;
use ptmc::tensor::synth::{frostt_suite, generate};

/// Scale factor between our suite and FROSTT (DESIGN.md §2).
const SCALE: f64 = 1000.0;

fn main() {
    let mut table = Table::new(&[
        "tensor", "modes", "max mode len", "nnz", "tensor bytes", "factor bytes(R=16)",
        "density",
    ]);

    let mut max_mode_len_scaled: f64 = 0.0;
    let mut max_nnz_scaled: f64 = 0.0;
    let mut modes_seen = std::collections::HashSet::new();
    let mut max_tensor_gb_scaled: f64 = 0.0;
    let mut max_factor_gb_scaled: f64 = 0.0;

    for (name, cfg) in frostt_suite(11) {
        let t = generate(&cfg);
        let c = characteristics(&t, 16);
        modes_seen.insert(c.n_modes);
        max_mode_len_scaled = max_mode_len_scaled.max(c.max_mode_len as f64 * SCALE);
        max_nnz_scaled = max_nnz_scaled.max(c.nnz as f64 * SCALE);
        max_tensor_gb_scaled =
            max_tensor_gb_scaled.max(c.tensor_bytes as f64 * SCALE / 1e9);
        max_factor_gb_scaled =
            max_factor_gb_scaled.max(c.max_factor_bytes as f64 * SCALE / 1e9);
        table.row(&[
            name.to_string(),
            c.n_modes.to_string(),
            c.max_mode_len.to_string(),
            c.nnz.to_string(),
            c.tensor_bytes.to_string(),
            c.max_factor_bytes.to_string(),
            format!("{:.2e}", c.density),
        ]);
    }
    table.emit(
        "Table 2 — characteristics of the scaled FROSTT-like suite",
        Some(std::path::Path::new("bench_results/table2.csv")),
    );

    // Paper ranges (Table 2), after scaling back up:
    let mut check = Table::new(&["metric", "paper", "suite x1000", "in range?"]);
    let rows: Vec<(&str, &str, String, bool)> = vec![
        (
            "length of a tensor mode",
            "17-39 M",
            format!("{:.1} M (max)", max_mode_len_scaled / 1e6),
            (17e6..=39.5e6).contains(&max_mode_len_scaled),
        ),
        (
            "number of non-zeros",
            "3-144 M",
            format!("{:.0} M (max)", max_nnz_scaled / 1e6),
            (3e6..=145e6).contains(&max_nnz_scaled),
        ),
        (
            "number of modes",
            "3, 4, 5",
            format!("{modes_seen:?}"),
            modes_seen == [3usize, 4, 5].into_iter().collect(),
        ),
        (
            "tensor size",
            "<= 2.25 GB",
            format!("{max_tensor_gb_scaled:.2} GB (max)"),
            max_tensor_gb_scaled <= 2.25,
        ),
        (
            "size of a factor matrix",
            "< 4.9 GB",
            format!("{max_factor_gb_scaled:.2} GB (max)"),
            max_factor_gb_scaled < 4.9,
        ),
    ];
    let mut all_ok = true;
    for (m, p, s, ok) in rows {
        all_ok &= ok;
        check.row(&[m.into(), p.into(), s, ok.to_string()]);
    }
    check.emit("Table 2 range check (paper vs suite x scale)", None);
    assert!(all_ok, "suite drifted outside the paper's Table-2 ranges");
    println!("all Table 2 characteristics in range at 1/{SCALE} scale. OK");
}
