//! E5 — Cache Engine design-space sweep (§5.2.1/§5.3): total memory
//! access time vs cache size / line width / associativity, measured on
//! the cycle simulator, with the PMS estimate side by side and BRAM cost
//! from the FPGA resource model.  The interesting feature is the *knee*:
//! time falls until the hot factor-row working set fits, then plateaus
//! while BRAM cost keeps growing — the point the DSE must find.

use ptmc::bench::{fmt_cycles, fmt_speedup, sized, smoke, Table};
use ptmc::controller::{CacheConfig, ControllerConfig, MemLayout, MemoryController};
use ptmc::cpd::linalg::Mat;
use ptmc::engine::{EngineKind, PreparedTrace};
use ptmc::fpga::{self, Device};
use ptmc::mttkrp::{approach1, Tracing};
use ptmc::pms::{self, TensorProfile};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};

fn main() {
    let rank = 16usize;
    let t_base = generate(&SynthConfig {
        dims: vec![sized(8_000, 800), sized(5_000, 500), sized(3_000, 300)],
        nnz: sized(120_000, 8_000),
        profile: Profile::Zipf { alpha_milli: 1250 },
        seed: 13,
    });
    let factors: Vec<Mat> = t_base
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| Mat::randn(d, rank, m as u64))
        .collect();
    let layout = MemLayout::plan(t_base.dims(), t_base.nnz(), t_base.record_bytes(), rank);
    let profile = TensorProfile::measure(&t_base);
    let dev = Device::alveo_u250();

    // Pre-sort once; the sweep measures the compute trace only.
    let mut t = t_base.clone();
    t.sort_by_mode(0);
    let run = approach1::run(&t, &factors, 0, &layout, Tracing::On);

    // --- Sweep 1: cache capacity (num_lines) ---
    let mut cap = Table::new(&[
        "num_lines", "capacity", "sim cycles", "pms cycles", "hit rate", "BRAM36",
    ]);
    let mut prev_cycles = u64::MAX;
    let mut knee_seen = false;
    for &num_lines in &[64usize, 256, 1024, 4096, 16384, 65536] {
        let mut cfg = ControllerConfig::default_for(t.record_bytes());
        cfg.cache = CacheConfig {
            line_bytes: 64,
            num_lines,
            assoc: 4,
            hit_latency: 2,
        };
        let mut ctl = MemoryController::new(cfg.clone());
        let cycles = ctl.replay(&run.trace);
        let est = pms::estimate_with_rank(&profile, &cfg, &dev, rank);
        // Compare against the PMS mode-0 compute estimate (no remap).
        let pms_mode0 = est.per_mode[0].total();
        let usage = fpga::estimate(&cfg, &dev);
        cap.row(&[
            num_lines.to_string(),
            format!("{} KiB", cfg.cache.capacity_bytes() / 1024),
            fmt_cycles(cycles),
            format!("{:.0}", pms_mode0),
            format!("{:.1}%", 100.0 * ctl.cache_stats().hit_rate()),
            usage.bram36_used.to_string(),
        ]);
        if prev_cycles != u64::MAX {
            let gain = prev_cycles as f64 / cycles as f64;
            if gain < 1.02 {
                knee_seen = true; // plateau reached
            }
        }
        prev_cycles = cycles;
    }
    cap.emit(
        "E5a — cache capacity sweep (mode-0 compute trace)",
        Some(std::path::Path::new("bench_results/dse_cache_capacity.csv")),
    );
    if !smoke() {
        assert!(knee_seen, "expected a capacity knee/plateau");
    }

    // --- Engine comparison on the same sweep's replay loop ---
    // Same trace, same configs, lockstep vs event core; scores must be
    // bit-identical, only wall-clock differs.
    let prepared = PreparedTrace::new(run.trace.clone());
    let sweep_cfgs: Vec<ControllerConfig> = [256usize, 1024, 4096, 16384]
        .iter()
        .map(|&num_lines| {
            let mut cfg = ControllerConfig::default_for(t.record_bytes());
            cfg.cache = CacheConfig {
                line_bytes: 64,
                num_lines,
                assoc: 4,
                hit_latency: 2,
            };
            cfg
        })
        .collect();
    let score_all = |engine: EngineKind| -> (Vec<u64>, f64) {
        let t0 = std::time::Instant::now();
        let scores = sweep_cfgs
            .iter()
            .map(|cfg| {
                let mut ctl = MemoryController::new(cfg.clone());
                engine.replay(&mut ctl, &prepared)
            })
            .collect();
        (scores, t0.elapsed().as_secs_f64() * 1e3)
    };
    let _ = score_all(EngineKind::Lockstep); // warm-up
    let (lockstep_scores, lockstep_ms) = score_all(EngineKind::Lockstep);
    let (event_scores, event_ms) = score_all(EngineKind::Event);
    assert_eq!(lockstep_scores, event_scores, "engines must agree");
    println!(
        "engine replay comparison: lockstep {lockstep_ms:.0} ms, event {event_ms:.0} ms \
         ({}), trace compression {:.1}x",
        fmt_speedup(lockstep_ms / event_ms),
        prepared.compressed().compression_ratio()
    );

    // --- Sweep 2: line width at fixed capacity ---
    let mut line = Table::new(&["line_bytes", "num_lines", "sim cycles", "hit rate"]);
    for &line_bytes in &[32usize, 64, 128, 256, 512] {
        let num_lines = (256 * 1024) / line_bytes; // fixed 256 KiB
        let mut cfg = ControllerConfig::default_for(t.record_bytes());
        cfg.cache = CacheConfig {
            line_bytes,
            num_lines,
            assoc: 4,
            hit_latency: 2,
        };
        let mut ctl = MemoryController::new(cfg);
        let cycles = ctl.replay(&run.trace);
        line.row(&[
            line_bytes.to_string(),
            num_lines.to_string(),
            fmt_cycles(cycles),
            format!("{:.1}%", 100.0 * ctl.cache_stats().hit_rate()),
        ]);
    }
    line.emit(
        "E5b — line-width sweep at fixed 256 KiB capacity",
        Some(std::path::Path::new("bench_results/dse_cache_line.csv")),
    );

    // --- Sweep 3: associativity at fixed geometry ---
    let mut assoc_t = Table::new(&["assoc", "sim cycles", "hit rate"]);
    let mut results = Vec::new();
    for &assoc in &[1usize, 2, 4, 8, 16] {
        let mut cfg = ControllerConfig::default_for(t.record_bytes());
        cfg.cache = CacheConfig {
            line_bytes: 64,
            num_lines: 4096,
            assoc,
            hit_latency: 2,
        };
        let mut ctl = MemoryController::new(cfg);
        let cycles = ctl.replay(&run.trace);
        results.push((assoc, cycles));
        assoc_t.row(&[
            assoc.to_string(),
            fmt_cycles(cycles),
            format!("{:.1}%", 100.0 * ctl.cache_stats().hit_rate()),
        ]);
    }
    assoc_t.emit(
        "E5c — associativity sweep (4096 lines x 64 B)",
        Some(std::path::Path::new("bench_results/dse_cache_assoc.csv")),
    );
    // Direct-mapped must be the worst (conflict misses on zipf rows).
    if !smoke() {
        let dm = results[0].1;
        assert!(
            results[1..].iter().all(|&(_, c)| c <= dm),
            "higher associativity should not lose to direct-mapped"
        );
    }
    println!("cache DSE shapes OK: capacity knee, line-width optimum, assoc monotone");
}
