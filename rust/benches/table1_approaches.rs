//! E1 — regenerate paper Table 1: Approach 1 vs Approach 2 on total
//! computations, total external memory accesses, and partial-sum size.
//!
//! For each (N, R) cell we run both instrumented engines on the same
//! tensor, compare measured counts to the closed forms, and additionally
//! replay both traces through the memory controller to show the paper's
//! qualitative conclusion (Approach 1 wins) in *cycles*, not just counts.

use ptmc::bench::{fmt_cycles, sized, smoke, Table};
use ptmc::controller::{ControllerConfig, MemLayout, MemoryController};
use ptmc::cpd::linalg::Mat;
use ptmc::mttkrp::counts::{table1_accesses_a1, table1_accesses_a2};
use ptmc::mttkrp::{approach1, approach2, Tracing};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};

fn main() {
    let mut table = Table::new(&[
        "N", "R", "approach", "computations", "accesses(meas)", "accesses(paper)",
        "partials", "cycles", "A1 speedup",
    ]);

    for (n_modes, dims) in [
        (3usize, vec![900usize, 700, 500]),
        (4, vec![500, 400, 300, 100]),
    ] {
        for &r in &[8usize, 16, 32] {
            let t = generate(&SynthConfig {
                dims: dims.clone(),
                nnz: sized(40_000, 4_000),
                profile: Profile::Zipf { alpha_milli: 1200 },
                seed: 99,
            });
            let factors: Vec<Mat> = t
                .dims()
                .iter()
                .enumerate()
                .map(|(m, &d)| Mat::randn(d, r, m as u64))
                .collect();
            let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), r);
            let nnz = t.nnz() as u64;

            // Approach 1 (tensor sorted by output mode 0).
            let mut t1 = t.clone();
            t1.sort_by_mode(0);
            let a1 = approach1::run(&t1, &factors, 0, &layout, Tracing::On);
            let mut ctl = MemoryController::new(ControllerConfig::default_for(t.record_bytes()));
            let a1_cycles = ctl.replay(&a1.trace);

            // Approach 2 (tensor sorted by input mode 1).
            let mut t2 = t.clone();
            t2.sort_by_mode(1);
            let a2 = approach2::run(&t2, &factors, 0, 1, &layout, Tracing::On);
            let mut ctl2 = MemoryController::new(ControllerConfig::default_for(t.record_bytes()));
            let a2_cycles = ctl2.replay(&a2.trace);

            let i_out = t.dims()[0] as u64;
            let i_in = t.dims()[1] as u64;
            let speedup = a2_cycles as f64 / a1_cycles as f64;

            table.row(&[
                n_modes.to_string(),
                r.to_string(),
                "1 (output-dir)".into(),
                a1.counts.compute_ops.to_string(),
                a1.counts.total_accesses().to_string(),
                table1_accesses_a1(nnz, n_modes as u64, r as u64, i_out).to_string(),
                "0".into(),
                fmt_cycles(a1_cycles),
                format!("{speedup:.2}x"),
            ]);
            table.row(&[
                n_modes.to_string(),
                r.to_string(),
                "2 (input-dir)".into(),
                a2.counts.compute_ops.to_string(),
                a2.counts.total_accesses().to_string(),
                table1_accesses_a2(nnz, n_modes as u64, r as u64, i_in).to_string(),
                (a2.counts.partial_stores).to_string(),
                fmt_cycles(a2_cycles),
                "-".into(),
            ]);

            // The paper's qualitative claims, enforced (the exact count
            // identity holds at any scale; the cycle race needs the
            // full-size workload):
            assert_eq!(a1.counts.compute_ops, a2.counts.compute_ops);
            assert!(a1.counts.total_accesses() < a2.counts.total_accesses());
            if !smoke() {
                assert!(a1_cycles < a2_cycles, "Approach 1 must win in cycles");
            }
        }
    }

    table.emit(
        "Table 1 — comparison of the approaches (measured vs closed form)",
        Some(std::path::Path::new("bench_results/table1.csv")),
    );
    println!(
        "Shape check vs paper: equal computations, A2 carries |T|*R partials\n\
         and loses on accesses and cycles in every cell. OK"
    );
}
