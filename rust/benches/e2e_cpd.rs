//! E8 (bench form) — end-to-end CP-ALS iteration time per backend and
//! per segment-encoding variant (the D2 ablation: one-hot matmul vs
//! in-graph one-hot vs jnp segment-sum), on a medium FROSTT-like tensor.
//!
//! Requires `make artifacts` for the PJRT rows; they are skipped (with a
//! note) when artifacts are missing so `cargo bench` stays green.

use std::path::Path;

use ptmc::bench::{sized, time, Table};
use ptmc::controller::{ControllerConfig, MemLayout, MemoryController};
use ptmc::coordinator::{PjrtCoordinator, SegMode};
use ptmc::cpd::{cp_als, AlsConfig, MttkrpBackend, NativeBackend, SimBackend};
use ptmc::runtime::Runtime;
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::tensor::SparseTensor;

fn tensor() -> SparseTensor {
    generate(&SynthConfig {
        dims: vec![sized(2_000, 400), sized(1_500, 300), sized(1_000, 200)],
        nnz: sized(50_000, 4_000),
        profile: Profile::Zipf { alpha_milli: 1250 },
        seed: 2022,
    })
}

fn als_cfg() -> AlsConfig {
    AlsConfig {
        rank: 16,
        max_iters: 2,
        tol: 0.0,
        ..Default::default()
    }
}

fn main() {
    let mut tbl = Table::new(&["backend", "mean/run (2 iters)", "final fit", "nnz/s"]);
    let cfg = als_cfg();
    let nnz_per_run = (tensor().nnz() * 3 * cfg.max_iters) as f64;

    // Native host compute.
    let mut fit = 0.0;
    let t_native = time(sized(1, 0) as u32, sized(3, 1) as u32, || {
        let mut t = tensor();
        let m = cp_als(&mut t, &cfg, &mut NativeBackend);
        fit = m.final_fit();
        m
    });
    tbl.row(&[
        "native (host)".into(),
        format!("{:?}", t_native.mean),
        format!("{fit:.5}"),
        format!("{:.0}", nnz_per_run / t_native.mean.as_secs_f64()),
    ]);

    // Memory-controller simulation.
    let t_sim = time(0, sized(2, 1) as u32, || {
        let mut t = tensor();
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), cfg.rank);
        let ctl = MemoryController::new(ControllerConfig::default_for(t.record_bytes()));
        let mut b = SimBackend::new(ctl, layout);
        let m = cp_als(&mut t, &cfg, &mut b);
        fit = m.final_fit();
        (m, b.cycles())
    });
    tbl.row(&[
        "sim (cycle model)".into(),
        format!("{:?}", t_sim.mean),
        format!("{fit:.5}"),
        format!("{:.0}", nnz_per_run / t_sim.mean.as_secs_f64()),
    ]);

    // PJRT variants.
    if Path::new("artifacts/manifest.txt").exists() {
        for (label, seg) in [
            ("pjrt onehot (MXU matmul)", SegMode::Onehot),
            ("pjrt onehot-jnp (no pallas)", SegMode::OnehotJnp),
            ("pjrt segids (in-graph onehot)", SegMode::SegIds),
            ("pjrt refseg (jnp segment-sum)", SegMode::RefSeg),
        ] {
            let t_p = time(1, 2, || {
                let rt = Runtime::open_default().expect("artifacts");
                let mut b = PjrtCoordinator::new(rt, seg);
                let mut t = tensor();
                let m = cp_als(&mut t, &cfg, &mut b);
                fit = m.final_fit();
                m
            });
            tbl.row(&[
                label.into(),
                format!("{:?}", t_p.mean),
                format!("{fit:.5}"),
                format!("{:.0}", nnz_per_run / t_p.mean.as_secs_f64()),
            ]);
        }
    } else {
        println!("[pjrt rows skipped: run `make artifacts`]");
    }

    tbl.emit(
        "E8 — CP-ALS end-to-end per backend (2 iterations, 50k nnz, R=16)",
        Some(std::path::Path::new("bench_results/e2e_cpd.csv")),
    );
}
