//! E10 — worker-scaling sweep for the sharded MTTKRP execution engine:
//! wall-clock time and simulated parallel makespan at 1/2/4/8 workers on
//! a >= 1M-nnz synthetic tensor (full 3-mode sweep, one simulated
//! memory-controller instance per worker).
//!
//! The headline number is the 1 -> 4 worker wall-clock speedup: the
//! sharding is output-disjoint, so workers never synchronize inside a
//! mode and the only losses are plan imbalance and per-worker cold
//! caches.

use std::path::Path;
use std::time::Instant;

use ptmc::bench::{fmt_cycles, fmt_speedup, sized, smoke, Table};
use ptmc::controller::{ControllerConfig, MemLayout};
use ptmc::cpd::linalg::Mat;
use ptmc::engine::EngineKind;
use ptmc::shard::{mttkrp_sharded, ShardPlan, ShardedSweep};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};

fn main() {
    let rank = 16usize;
    let nnz = sized(1_200_000, 40_000);
    println!("generating {nnz}-nnz zipf tensor...");
    let t = generate(&SynthConfig {
        dims: vec![
            sized(80_000, 8_000),
            sized(50_000, 5_000),
            sized(30_000, 3_000),
        ],
        nnz,
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed: 2022,
    });
    let factors: Vec<Mat> = t
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| Mat::randn(d, rank, m as u64))
        .collect();
    let cfg = ControllerConfig::default_for(t.record_bytes());
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);

    let sweep = |workers: usize| -> (f64, u64) {
        let t0 = Instant::now();
        let mut cycles = 0u64;
        for mode in 0..t.n_modes() {
            cycles += mttkrp_sharded(&t, &factors, mode, workers, Some((&cfg, &layout))).makespan;
        }
        (t0.elapsed().as_secs_f64() * 1e3, cycles)
    };

    // Warm up allocators / page cache once before measuring.
    if !smoke() {
        let _ = sweep(2);
    }

    let mut table = Table::new(&[
        "workers",
        "imbalance (worst mode)",
        "wall ms",
        "wall speedup",
        "sim cycles",
        "sim speedup",
    ]);
    let mut walls = Vec::new();
    let mut base_wall = 0.0f64;
    let mut base_cycles = 0u64;
    for &k in &[1usize, 2, 4, 8] {
        let (wall, cycles) = sweep(k);
        if k == 1 {
            base_wall = wall;
            base_cycles = cycles;
        }
        walls.push((k, wall));
        // The timed sweep covers every mode; report the worst plan.
        let imbalance = (0..t.n_modes())
            .map(|m| ShardPlan::balance(&t, m, k).imbalance())
            .fold(0.0f64, f64::max);
        table.row(&[
            k.to_string(),
            format!("{imbalance:.2}"),
            format!("{wall:.0}"),
            fmt_speedup(base_wall / wall),
            fmt_cycles(cycles),
            fmt_speedup(base_cycles as f64 / cycles as f64),
        ]);
    }
    table.emit(
        "worker scaling — sharded MTTKRP, 3-mode sweep, 1.2M nnz",
        Some(Path::new("bench_out/worker_scaling.csv")),
    );
    println!(
        "(sim model: one memory-controller instance and one DRAM channel \
         group per worker — multi-SLR scale-out, not one shared bus)"
    );

    let wall4 = walls
        .iter()
        .find(|(k, _)| *k == 4)
        .map(|(_, w)| *w)
        .unwrap();
    println!(
        "1 -> 4 workers: wall-clock {:.0} ms -> {:.0} ms ({})",
        base_wall,
        wall4,
        fmt_speedup(base_wall / wall4)
    );
    if wall4 >= base_wall && !smoke() {
        println!("WARNING: no wall-clock improvement at 4 workers on this host");
    }

    // --- DSE-scoring engine comparison at the same scale ---
    // One prepared sweep, scored under both replay cores: identical
    // makespans, different wall-clock (the event core batches replays,
    // runs shards concurrently, and memoizes the remap pass).
    let cfgs: Vec<ControllerConfig> = [256usize, 1024, 4096]
        .iter()
        .map(|&num_lines| {
            let mut c = cfg.clone();
            c.cache.num_lines = num_lines;
            c
        })
        .collect();
    let mut etbl = Table::new(&["engine", "configs scored", "wall ms", "speedup"]);
    let sweep4 = ShardedSweep::prepare(&t, rank, 4);
    let score = |engine: EngineKind| -> (Vec<u64>, f64) {
        let t0 = Instant::now();
        let scores = cfgs
            .iter()
            .map(|c| sweep4.makespan_with(c, engine))
            .collect();
        (scores, t0.elapsed().as_secs_f64() * 1e3)
    };
    let (lockstep_scores, lockstep_ms) = score(EngineKind::Lockstep);
    let (event_scores, event_ms) = score(EngineKind::Event);
    assert_eq!(lockstep_scores, event_scores, "engines must agree");
    etbl.row(&[
        "lockstep (legacy)".into(),
        cfgs.len().to_string(),
        format!("{lockstep_ms:.0}"),
        "1.00x".into(),
    ]);
    etbl.row(&[
        "event (batched)".into(),
        cfgs.len().to_string(),
        format!("{event_ms:.0}"),
        fmt_speedup(lockstep_ms / event_ms),
    ]);
    etbl.emit(
        "DSE scoring at scale — lockstep vs event engine (identical makespans)",
        Some(Path::new("bench_out/worker_scaling_engines.csv")),
    );
}
