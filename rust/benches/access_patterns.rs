//! E4 — the §4 access-pattern/transfer-type matrix: time each of the
//! paper's four spMTTKRP access patterns under each of the three §4
//! transfer types and confirm the paper's prescribed pairing is optimal
//! in every row.

use ptmc::bench::{fmt_cycles, sized, smoke, Table};
use ptmc::controller::{Access, ControllerConfig, MemoryController};
use ptmc::testkit::Rng;

fn bytes_per_pattern() -> usize {
    sized(2 << 20, 2 << 16)
}
const ROW_BYTES: usize = 64; // rank-16 factor row

fn replay(trace: &[Access]) -> u64 {
    let mut ctl = MemoryController::new(ControllerConfig::default_for(16));
    ctl.replay(trace)
}

/// Build a trace for `pattern` served via `transfer`.
fn trace(pattern: &str, transfer: &str, rng: &mut Rng) -> Vec<Access> {
    let addrs: Vec<(u64, usize)> = match pattern {
        // 1. tensor elements while remapping/computing: sequential bulk.
        "tensor stream" => (0..bytes_per_pattern() / 4096)
            .map(|i| ((i * 4096) as u64, 4096))
            .collect(),
        // 2. remapped element stores — measured as a *combined* workload
        // below (see `remap_store_trace`): the paper's reason for DMA
        // element transfers is "access data without polluting the cache"
        // (§5.1.2b), which only shows up when the store stream shares
        // the controller with the cached factor-row stream.
        "remap stores" => unreachable!("handled by remap_store_trace"),
        // 3. input factor rows: random with zipf temporal locality.
        "factor rows" => (0..bytes_per_pattern() / ROW_BYTES)
            .map(|_| {
                let row = rng.zipf(1 << 20, 1.2);
                ((8u64 << 30) + row * ROW_BYTES as u64, ROW_BYTES)
            })
            .collect(),
        // 4. output rows: streaming store of finished rows.
        "output rows" => (0..bytes_per_pattern() / ROW_BYTES)
            .map(|i| ((12u64 << 30) + (i * ROW_BYTES) as u64, ROW_BYTES))
            .collect(),
        _ => unreachable!(),
    };
    let is_store = pattern == "output rows";
    addrs
        .into_iter()
        .map(|(addr, bytes)| match transfer {
            "dma-stream" => Access::Stream { addr, bytes },
            "dma-element" => Access::Element { addr, bytes },
            "cache" if is_store => Access::CachedStore { addr, bytes },
            "cache" => Access::Cached { addr, bytes },
            _ => unreachable!(),
        })
        .collect()
}

/// Combined remap workload: element-wise stores to `parts` output
/// partitions interleaved with cached zipf factor-row loads.  `transfer`
/// routes the *stores*; the loads always use the cache (they are the
/// victim of pollution).
fn remap_store_trace(transfer: &str) -> Vec<Access> {
    let parts = 8192u64;
    let mut rng = Rng::new(42);
    let n = bytes_per_pattern() / 64;
    let mut out = Vec::with_capacity(2 * n);
    for i in 0..n {
        // One remapped 16-byte record store...
        let p = (i as u64) % parts;
        let off = (i as u64) / parts;
        let addr = (1u64 << 30) + p * (1 << 20) + off * 16;
        out.push(match transfer {
            "dma-stream" => Access::Stream { addr, bytes: 16 },
            "dma-element" => Access::Element { addr, bytes: 16 },
            // Stores through the cache are write-allocate/write-back.
            "cache" => Access::CachedStore { addr, bytes: 16 },
            _ => unreachable!(),
        });
        // ...interleaved with a cached factor-row load.
        let row = rng.zipf(1 << 17, 1.2);
        out.push(Access::Cached {
            addr: (8u64 << 30) + row * ROW_BYTES as u64,
            bytes: ROW_BYTES,
        });
    }
    out
}

fn main() {
    // The paper's prescribed pairing per pattern (§4).
    let prescribed = [
        ("tensor stream", "dma-stream"),
        ("remap stores", "dma-element"),
        ("factor rows", "cache"),
        ("output rows", "dma-stream"),
    ];
    let transfers = ["dma-stream", "dma-element", "cache"];

    let mut table = Table::new(&[
        "pattern", "dma-stream", "dma-element", "cache", "paper picks", "paper optimal?",
    ]);
    for (pattern, pick) in prescribed {
        let mut cells = Vec::new();
        let mut cycles = std::collections::HashMap::new();
        for tr in transfers {
            let c = if pattern == "remap stores" {
                replay(&remap_store_trace(tr))
            } else {
                let mut rng = Rng::new(42); // same addresses per transfer
                replay(&trace(pattern, tr, &mut rng))
            };
            cycles.insert(tr, c);
            cells.push(fmt_cycles(c));
        }
        let best = transfers.iter().min_by_key(|tr| cycles[**tr]).unwrap();
        // "Optimal" allows a tie within 2% (stream vs element on already
        // sequential element traffic can be close).
        let optimal =
            cycles[pick] as f64 <= 1.02 * cycles[*best] as f64;
        table.row(&[
            pattern.into(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            pick.into(),
            optimal.to_string(),
        ]);
        if !smoke() {
            assert!(
                optimal,
                "{pattern}: paper picks {pick} ({}) but {best} is faster ({})",
                cycles[pick], cycles[*best]
            );
        }
    }

    table.emit(
        "§4 access patterns x transfer types (cycles; lower is better)",
        Some(std::path::Path::new("bench_results/access_patterns.csv")),
    );
    println!("paper's pattern->engine routing is optimal in every row. OK");
}
