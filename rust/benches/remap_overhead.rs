//! E2 — the §3 remapping-overhead claim: communication overhead per mode
//! `2|T| / (|T| + (N-1)|T|R + I_out R) ≈ 2/(1+(N-1)R)`, under 6% for the
//! typical N=3–5, R=16–64 — measured against the real remap engine.

use ptmc::bench::{sized, smoke, Table};
use ptmc::controller::{ControllerConfig, MemLayout, MemoryController};
use ptmc::cpd::linalg::Mat;
use ptmc::mttkrp::remap_exec;
use ptmc::tensor::remap::{overhead_ratio, overhead_ratio_approx};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};

fn main() {
    let mut table = Table::new(&[
        "N", "R", "paper approx", "paper exact", "measured", "<6%?",
    ]);
    let mut worst: f64 = 0.0;

    for &n_modes in &[3usize, 4, 5] {
        // Scaled mode lengths; later modes shorter like real tensors.
        let dims: Vec<usize> = (0..n_modes).map(|m| 2_000 / (m + 1) + 50).collect();
        for &r in &[16usize, 32, 64] {
            let t = generate(&SynthConfig {
                dims: dims.clone(),
                nnz: sized(60_000, 6_000),
                profile: Profile::Zipf { alpha_milli: 1200 },
                seed: 7 + n_modes as u64,
            });
            let factors: Vec<Mat> = t
                .dims()
                .iter()
                .enumerate()
                .map(|(m, &d)| Mat::randn(d, r, m as u64))
                .collect();
            let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), r);
            let mut ctl = MemoryController::new(ControllerConfig::default_for(t.record_bytes()));

            // Measure the remap done for mode 1 (tensor arrives unsorted).
            let mut t_run = t.clone();
            t_run.sort_by_mode(0);
            let run = remap_exec::run(&mut t_run, &factors, 1, &layout, &mut ctl, 0);
            let measured = run.overhead_ratio();
            worst = worst.max(measured);

            let approx = overhead_ratio_approx(n_modes, r);
            let exact = overhead_ratio(t.nnz(), n_modes, r, t.dims()[1]);
            table.row(&[
                n_modes.to_string(),
                r.to_string(),
                format!("{:.3}%", 100.0 * approx),
                format!("{:.3}%", 100.0 * exact),
                format!("{:.3}%", 100.0 * measured),
                (measured < 0.06).to_string(),
            ]);
            if !smoke() {
                assert!(
                    measured < 0.06,
                    "paper claim violated: N={n_modes} R={r} overhead {measured}"
                );
            }
        }
    }

    table.emit(
        "§3 remapping communication overhead (paper claim: <6% for N=3-5, R=16-64)",
        Some(std::path::Path::new("bench_results/remap_overhead.csv")),
    );
    println!("worst measured overhead: {:.3}% — paper claim holds", 100.0 * worst);
}
