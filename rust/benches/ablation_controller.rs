//! E9 — memory-controller ablation: remove or cripple each module of the
//! paper's Fig.-4 controller and measure the regression on a full
//! Approach-1-with-remap sweep.  Quantifies each module's contribution —
//! the paper's implicit claim that all three are necessary.

use ptmc::bench::{fmt_cycles, fmt_speedup, sized, smoke, Table};
use ptmc::controller::{
    Access, CacheConfig, ControllerConfig, MemLayout, MemoryController,
};
use ptmc::cpd::linalg::Mat;
use ptmc::mttkrp::{approach1, Tracing};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::tensor::remap;

/// Full 3-mode sweep under `cfg`; `cache_enabled=false` routes factor
/// rows through element-wise DMA instead of the Cache Engine (the
/// "no cache" ablation).
fn sweep(cfg: &ControllerConfig, cache_enabled: bool, seed: u64) -> u64 {
    let mut t = generate(&SynthConfig {
        dims: vec![sized(6_000, 600), sized(4_000, 400), sized(2_500, 250)],
        nnz: sized(100_000, 8_000),
        profile: Profile::Zipf { alpha_milli: 1250 },
        seed,
    });
    let rank = 16;
    let factors: Vec<Mat> = t
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| Mat::randn(d, rank, m as u64))
        .collect();
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
    let mut ctl = MemoryController::new(cfg.clone());
    for mode in 0..3 {
        ctl.remap_pass(t.mode_col(mode), t.dims()[mode], &layout, 0, 1);
        remap::remap(&mut t, mode, cfg.remapper.max_pointers);
        let run = approach1::run(&t, &factors, mode, &layout, Tracing::On);
        if cache_enabled {
            ctl.replay(&run.trace);
        } else {
            for a in &run.trace {
                match *a {
                    Access::Cached { addr, bytes } => {
                        ctl.request(Access::Element { addr, bytes });
                    }
                    other => {
                        ctl.request(other);
                    }
                }
            }
        }
    }
    ctl.now()
}

fn main() {
    let base_cfg = ControllerConfig::default_for(16);
    let seed = 31;
    let base = sweep(&base_cfg, true, seed);

    let mut tbl = Table::new(&["variant", "cycles", "slowdown vs full"]);
    tbl.row(&["full controller (paper Fig. 4)".into(), fmt_cycles(base), "1.00x".into()]);

    let mut record = |name: &str, cycles: u64| {
        tbl.row(&[
            name.into(),
            fmt_cycles(cycles),
            fmt_speedup(cycles as f64 / base as f64),
        ]);
        cycles
    };

    // A. No Cache Engine: factor rows via element-wise DMA.
    let no_cache = record("no cache engine", sweep(&base_cfg, false, seed));

    // B. Tiny cache (64 lines direct-mapped).
    let mut tiny = base_cfg.clone();
    tiny.cache = CacheConfig {
        line_bytes: 64,
        num_lines: 64,
        assoc: 1,
        hit_latency: 2,
    };
    let tiny_cache = record("tiny direct-mapped cache", sweep(&tiny, true, seed));

    // C. Crippled DMA: one DMA, one 512 B buffer.
    let mut one_dma = base_cfg.clone();
    one_dma.dma.num_dmas = 1;
    one_dma.dma.buffers_per_dma = 1;
    one_dma.dma.buffer_bytes = 512;
    one_dma.remapper.buffer_bytes = 512;
    let crippled_dma = record("single 512B DMA buffer", sweep(&one_dma, true, seed));

    // D. Pointer spill: remapper tracks only 256 pointers on-chip.
    let mut spill = base_cfg.clone();
    spill.remapper.max_pointers = 256;
    let ptr_spill = record("256 on-chip pointers (spill)", sweep(&spill, true, seed));

    tbl.emit(
        "E9 — controller module ablation (3-mode sweep, 100k nnz)",
        Some(std::path::Path::new("bench_results/ablation.csv")),
    );

    if !smoke() {
        assert!(no_cache > base, "cache must matter");
        assert!(tiny_cache > base, "cache capacity must matter");
        assert!(crippled_dma > base, "DMA buffering must matter");
        assert!(ptr_spill > base, "pointer budget must matter");
    }
    println!("every module contributes; removing any regresses. OK");
}
