//! Property tests for the warm-start DSE layer (S28,
//! `ptmc::dse::warm`): a warm-started `explore_with` must return a
//! byte-identical `Exploration` to a cold run (first *and* repeat
//! queries), a perturbed tensor must never hit a stale cache, and the
//! on-disk cache must survive a round-trip while tolerating truncated
//! or corrupt files by falling back to cold.

use std::path::PathBuf;
use std::sync::Arc;

use ptmc::controller::ControllerConfig;
use ptmc::dram::RowPolicy;
use ptmc::dse::{
    explore_with, tensor_fingerprint, EvaluatorBuilder, Exploration, Grids, KeyBuilder, Point,
    SearchOptions, SearchStrategy, WarmCache,
};
use ptmc::fpga::Device;
use ptmc::mem::MemTech;
use ptmc::pms::TensorProfile;
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::tensor::SparseTensor;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ptmc_warm_props_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn tensor(seed: u64) -> SparseTensor {
    generate(&SynthConfig {
        dims: vec![120, 90, 60],
        nnz: 3_000,
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed,
    })
}

fn small_grids() -> Grids {
    Grids {
        cache_line_bytes: vec![32, 64],
        cache_num_lines: vec![256, 1024],
        cache_assoc: vec![2, 4],
        dma_num: vec![1, 2],
        dma_buffers: vec![2],
        dma_buffer_bytes: vec![4096],
        mem_techs: vec![MemTech::Ddr4],
        dram_channels: vec![1, 2],
        dram_banks: vec![16],
        dram_row_policy: vec![RowPolicy::Open],
        remap_max_pointers: vec![1 << 10, 1 << 18],
    }
}

fn pms_key(t: &SparseTensor, dev: &Device) -> u64 {
    KeyBuilder::new(tensor_fingerprint(t))
        .evaluator("pms")
        .rank(16)
        .device(dev)
        .finish()
}

fn assert_points_identical(a: &[Point], b: &[Point], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: lengths diverged");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.cfg, y.cfg, "{what}: configs diverged");
        assert_eq!(
            x.cycles.to_bits(),
            y.cycles.to_bits(),
            "{what}: cycles diverged"
        );
        assert_eq!(x.bram36, y.bram36, "{what}: bram36 diverged");
        assert_eq!(x.uram, y.uram, "{what}: uram diverged");
    }
}

fn assert_explorations_identical(a: &Exploration, b: &Exploration) {
    assert_points_identical(
        std::slice::from_ref(&a.best),
        std::slice::from_ref(&b.best),
        "best",
    );
    assert_points_identical(&a.visited, &b.visited, "visited");
    assert_eq!(a.rejected, b.rejected, "rejected counts diverged");
    assert_points_identical(&a.pareto, &b.pareto, "pareto");
    assert_points_identical(&a.top, &b.top, "top-k");
}

#[test]
fn warm_explore_is_byte_identical_to_cold_and_reuses_scores() {
    let t = tensor(11);
    let profile = TensorProfile::measure(&t);
    let base = ControllerConfig::default_for(t.record_bytes());
    let dev = Device::alveo_u250();
    // The full default grid plus a never-fits cache point so the
    // search genuinely prunes: the rejected count doubles as the
    // regression that warm queries prune exactly like cold ones, with
    // infeasible verdicts replayed from the cache rather than
    // re-derived.
    let mut grids = Grids::default();
    grids.cache_num_lines.push(1 << 22);
    let opts = SearchOptions {
        strategy: SearchStrategy::Coordinate,
        top_k: 3,
        resume: false,
        checkpoint_every: 0,
    };

    let cold_eval = EvaluatorBuilder::new().rank(16).pms(&profile);
    let cold = explore_with(&base, &grids, &dev, &cold_eval, &opts);
    assert!(cold.rejected > 0, "the default grid should prune on u250");

    let dir = tmp_dir("identical");
    let key = pms_key(&t, &dev);

    // First warm run (empty cache): already byte-identical to cold.
    let cache = Arc::new(WarmCache::open(&dir, key));
    let warm = Some(Arc::clone(&cache));
    let eval = EvaluatorBuilder::new().rank(16).warm_cache(warm).pms(&profile);
    let first = explore_with(&base, &grids, &dev, &eval, &opts);
    assert_explorations_identical(&cold, &first);
    assert!(cache.misses() > 0, "first run must populate the cache");

    // Second warm run, cache reloaded from disk: byte-identical again
    // and served entirely from the cache — zero re-scores, and the
    // pruned count matches the cold path without re-pruning.
    let cache2 = Arc::new(WarmCache::open(&dir, key));
    assert!(!cache2.is_empty(), "cache must round-trip through disk");
    let warm2 = Some(Arc::clone(&cache2));
    let eval2 = EvaluatorBuilder::new().rank(16).warm_cache(warm2).pms(&profile);
    let second = explore_with(&base, &grids, &dev, &eval2, &opts);
    assert_explorations_identical(&cold, &second);
    assert!(cache2.hits() > 0, "repeat query must hit the cache");
    assert_eq!(cache2.misses(), 0, "repeat query must not re-score");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_fingerprint_never_hits_the_cache() {
    let t1 = tensor(17);
    // The same generator config perturbed by a single extra non-zero:
    // the fingerprint, and therefore the context key and cache file,
    // must change.
    let t2 = generate(&SynthConfig {
        dims: vec![120, 90, 60],
        nnz: 3_001,
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed: 17,
    });
    assert_ne!(
        tensor_fingerprint(&t1),
        tensor_fingerprint(&t2),
        "a one-nnz perturbation must change the fingerprint"
    );

    let dev = Device::alveo_u250();
    let dir = tmp_dir("stale");
    let profile = TensorProfile::measure(&t1);
    let base = ControllerConfig::default_for(t1.record_bytes());
    let key1 = pms_key(&t1, &dev);
    let cache1 = Arc::new(WarmCache::open(&dir, key1));
    let warm = Some(Arc::clone(&cache1));
    let eval = EvaluatorBuilder::new().rank(16).warm_cache(warm).pms(&profile);
    let opts = SearchOptions::default();
    explore_with(&base, &small_grids(), &dev, &eval, &opts);
    assert!(!cache1.is_empty(), "first tensor must populate its cache");

    let key2 = pms_key(&t2, &dev);
    assert_ne!(key1, key2, "perturbed tensor must change the key");
    let cache2 = WarmCache::open(&dir, key2);
    assert!(cache2.is_empty(), "perturbed tensor must start cold");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_cache_files_fall_back_to_cold_results() {
    let t = tensor(19);
    let profile = TensorProfile::measure(&t);
    let base = ControllerConfig::default_for(t.record_bytes());
    let dev = Device::alveo_u250();
    let grids = small_grids();
    let opts = SearchOptions {
        strategy: SearchStrategy::Coordinate,
        top_k: 2,
        resume: false,
        checkpoint_every: 0,
    };
    let cold_eval = EvaluatorBuilder::new().rank(16).pms(&profile);
    let cold = explore_with(&base, &grids, &dev, &cold_eval, &opts);

    let dir = tmp_dir("corrupt");
    let key = pms_key(&t, &dev);
    let cache = Arc::new(WarmCache::open(&dir, key));
    let warm = Some(Arc::clone(&cache));
    let eval = EvaluatorBuilder::new().rank(16).warm_cache(warm).pms(&profile);
    explore_with(&base, &grids, &dev, &eval, &opts);
    let path = cache.path();
    let good = std::fs::read(&path).expect("cache file must exist");

    // Truncate the file: reopening must fall back to cold and the
    // exploration must still be byte-identical.
    std::fs::write(&path, &good[..good.len() / 3]).unwrap();
    let cache2 = Arc::new(WarmCache::open(&dir, key));
    assert!(cache2.is_empty(), "truncated file must read as cold");
    let warm2 = Some(Arc::clone(&cache2));
    let eval2 = EvaluatorBuilder::new().rank(16).warm_cache(warm2).pms(&profile);
    let again = explore_with(&base, &grids, &dev, &eval2, &opts);
    assert_explorations_identical(&cold, &again);

    // The run over the corrupt file re-flushed a valid cache.
    let cache3 = WarmCache::open(&dir, key);
    assert!(!cache3.is_empty(), "explore must heal the cache file");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn beam_resume_restarts_from_the_stored_frontier() {
    let t = tensor(23);
    let profile = TensorProfile::measure(&t);
    let base = ControllerConfig::default_for(t.record_bytes());
    let dev = Device::alveo_u250();
    let grids = small_grids();
    let opts = SearchOptions {
        strategy: SearchStrategy::Beam { width: 2 },
        top_k: 3,
        resume: false,
        checkpoint_every: 0,
    };
    let cold_eval = EvaluatorBuilder::new().rank(16).pms(&profile);
    let cold = explore_with(&base, &grids, &dev, &cold_eval, &opts);

    let dir = tmp_dir("resume");
    let key = pms_key(&t, &dev);
    let cache = Arc::new(WarmCache::open(&dir, key));
    let warm = Some(Arc::clone(&cache));
    let eval = EvaluatorBuilder::new().rank(16).warm_cache(warm).pms(&profile);
    let first = explore_with(&base, &grids, &dev, &eval, &opts);
    assert_explorations_identical(&cold, &first);
    assert!(
        !cache.frontier().is_empty(),
        "explore must store a frontier"
    );

    // Resumed run: seeds the beam from the stored frontier.  It may
    // visit a different (seed-extended) set of points, but it must
    // never end worse than the cold search, and it must reuse scores.
    let cache2 = Arc::new(WarmCache::open(&dir, key));
    let warm2 = Some(Arc::clone(&cache2));
    let eval2 = EvaluatorBuilder::new().rank(16).warm_cache(warm2).pms(&profile);
    let resume_opts = SearchOptions {
        resume: true,
        ..opts
    };
    let resumed = explore_with(&base, &grids, &dev, &eval2, &resume_opts);
    assert!(
        resumed.best.cycles <= cold.best.cycles,
        "resume must never end worse than cold"
    );
    assert!(cache2.hits() > 0, "resume must reuse cached scores");
    let _ = std::fs::remove_dir_all(&dir);
}
