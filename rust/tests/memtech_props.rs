//! Property suite for the memory-technology abstraction
//! (`ptmc::mem`): the DDR4 [`MemoryDevice`] instance must be
//! **bit-identical** — per-access completion cycles, every statistics
//! counter, and the final makespan — to the pre-refactor raw
//! [`Dram`] model on random tensors, shard-trace access streams, and
//! adversarial mixes, across a DDR4 configuration grid; HBM2 must
//! stream at least as fast as DDR4 on sequential runs; and the
//! optical-SRAM scratchpad must never charge an activate or precharge
//! (its row counters stay 0 forever).

use ptmc::controller::{Access, ControllerConfig, MemLayout, MemoryController};
use ptmc::dram::{Dram, DramConfig, DramStats, RowPolicy};
use ptmc::engine::{EngineKind, PreparedTrace};
use ptmc::mem::{Hbm2Config, MemDevice, MemTech, MemTechConfig, MemoryDevice, OsramConfig};
use ptmc::shard::{partition_indices, shard_trace, ShardPlan};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::tensor::SparseTensor;
use ptmc::testkit::{forall, Rng};

/// A random synthetic tensor: 3 or 4 modes, varying nnz and skew.
fn random_tensor(rng: &mut Rng) -> SparseTensor {
    let n_modes = rng.range(3, 5);
    let dims: Vec<usize> = (0..n_modes).map(|_| rng.range(30, 300)).collect();
    let space: usize = dims.iter().product();
    let nnz = rng.range(1, 1_500).min(space / 4).max(1);
    let profile = match rng.below(3) {
        0 => Profile::Uniform,
        1 => Profile::Zipf {
            alpha_milli: 1_050 + rng.below(500) as u32,
        },
        _ => Profile::Clustered {
            block: 8,
            blocks: 20,
        },
    };
    generate(&SynthConfig {
        dims,
        nnz,
        profile,
        seed: rng.next_u64(),
    })
}

/// The `(addr, len)` stream a shard trace would present to external
/// memory, taken straight off the trace accesses.
fn addr_stream(trace: &[Access]) -> Vec<(u64, usize)> {
    trace
        .iter()
        .map(|a| match *a {
            Access::Stream { addr, bytes }
            | Access::Element { addr, bytes }
            | Access::Cached { addr, bytes }
            | Access::CachedStore { addr, bytes } => (addr, bytes.max(1)),
        })
        .collect()
}

/// Replay an access stream through the [`MemoryDevice`] trait,
/// chaining completion cycles, and return (per-access cycles, stats,
/// makespan).  Generic so the dispatch genuinely goes through the
/// trait surface the engines use.
fn replay<M: MemoryDevice>(dev: &mut M, accs: &[(u64, usize)]) -> (Vec<u64>, DramStats, u64) {
    let mut t = 0u64;
    let mut cycles = Vec::with_capacity(accs.len());
    for &(addr, len) in accs {
        t = dev.access(addr, len, t);
        cycles.push(t);
    }
    (cycles, dev.stats().clone(), dev.makespan())
}

/// The DDR4 configuration grid the identity must hold on: channels x
/// banks x row policy around the default timing set.
fn ddr4_grid() -> Vec<DramConfig> {
    let mut grid = Vec::new();
    for &channels in &[1usize, 2, 4] {
        for &banks in &[8usize, 16] {
            for &row_policy in &[RowPolicy::Open, RowPolicy::Closed] {
                let mut c = DramConfig::default_ddr4();
                c.channels = channels;
                c.banks = banks;
                c.row_policy = row_policy;
                grid.push(c);
            }
        }
    }
    grid
}

/// Assert the DDR4 trait instance reproduces the raw pre-refactor
/// `Dram` bit for bit on one access stream, for every grid config.
fn assert_ddr4_identity(accs: &[(u64, usize)], what: &str) {
    for c in ddr4_grid() {
        let mut raw = Dram::new(c.clone());
        let mut dev = MemDevice::new(&MemTechConfig::Ddr4(c.clone()));
        let (raw_cycles, raw_stats, raw_span) = replay(&mut raw, accs);
        let (dev_cycles, dev_stats, dev_span) = replay(&mut dev, accs);
        assert_eq!(raw_cycles, dev_cycles, "{what}: cycles diverged for {c:?}");
        assert_eq!(raw_stats, dev_stats, "{what}: stats diverged for {c:?}");
        assert_eq!(raw_span, dev_span, "{what}: makespan diverged for {c:?}");
        // Reset must restore a fresh epoch on both sides.
        MemoryDevice::reset(&mut raw);
        dev.reset();
        assert_eq!(raw.stats(), dev.stats(), "{what}: reset diverged");
        assert_eq!(Dram::makespan(&raw), dev.makespan());
    }
}

#[test]
fn ddr4_trait_instance_is_bit_identical_on_shard_traces() {
    forall("memtech_ddr4_identity_shard_traces", 6, |rng| {
        let t = random_tensor(rng);
        let rank = [4usize, 8, 16][rng.range(0, 3)];
        let mode = rng.range(0, t.n_modes());
        let workers = rng.range(1, 4);
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let plan = ShardPlan::balance(&t, mode, workers);
        let parts = partition_indices(&t, &plan);
        let mut offset = 0usize;
        for (spec, zs) in plan.shards.iter().zip(&parts) {
            let trace = shard_trace(&t, rank, mode, &layout, spec, zs, offset);
            offset += spec.nnz;
            assert_ddr4_identity(&addr_stream(&trace), "shard trace");
        }
    });
}

#[test]
fn ddr4_trait_instance_is_bit_identical_on_adversarial_streams() {
    // Unaligned addresses, giant and single-byte transfers, far-apart
    // rows, and dense same-row runs — every row-outcome path of the
    // bank model.
    forall("memtech_ddr4_identity_adversarial", 10, |rng| {
        let n = rng.range(1, 800);
        let mut accs = Vec::with_capacity(n);
        for i in 0..n as u64 {
            let (addr, len) = match rng.below(5) {
                0 => (i * 64, 64usize),
                1 => (rng.below(1 << 34), 1 + rng.below(16_384) as usize),
                2 => (rng.below(1 << 13), 1 + rng.below(64) as usize),
                3 => ((i % 3) * (1 << 30), 4096),
                _ => (rng.below(1 << 26) | 1, 1 + rng.below(700) as usize),
            };
            accs.push((addr, len));
        }
        assert_ddr4_identity(&accs, "adversarial stream");
    });
}

#[test]
fn ddr4_controller_default_is_the_trait_default() {
    // The controller's default configuration is the DDR4 technology
    // with the pre-refactor knob set, and replaying a shard trace
    // through it is deterministic across controller rebuilds.
    let cfg = ControllerConfig::default_for(16);
    assert_eq!(cfg.mem, MemTechConfig::default_ddr4());
    assert_eq!(cfg.mem.tech(), MemTech::Ddr4);
    assert_eq!(
        cfg.mem.ddr4().expect("default is DDR4"),
        &DramConfig::default_ddr4()
    );

    let t = generate(&SynthConfig {
        dims: vec![200, 150, 100],
        nnz: 3_000,
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed: 7,
    });
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
    let plan = ShardPlan::balance(&t, 0, 1);
    let parts = partition_indices(&t, &plan);
    let trace = shard_trace(&t, 8, 0, &layout, &plan.shards[0], &parts[0], 0);
    let prepared = PreparedTrace::new(trace);
    let runs: Vec<(u64, DramStats)> = (0..2)
        .map(|_| {
            let mut ctl = MemoryController::new(cfg.clone());
            let cycles = EngineKind::Event.replay(&mut ctl, &prepared);
            (cycles, ctl.dram_stats().clone())
        })
        .collect();
    assert_eq!(runs[0], runs[1], "controller replay must be deterministic");
    assert!(runs[0].0 > 0 && runs[0].1.bursts > 0);
}

#[test]
fn hbm2_streams_at_least_as_fast_as_ddr4() {
    // Closed-form: the analytic streaming bandwidth of the default
    // HBM2 part beats default DDR4.
    let ddr = MemTech::Ddr4.default_config();
    let hbm = MemTech::Hbm2.default_config();
    assert!(hbm.stream_bytes_per_cycle() >= ddr.stream_bytes_per_cycle());
    assert!(hbm.peak_bytes_per_cycle() >= ddr.peak_bytes_per_cycle());

    // Cycle model: on randomized sequential streaming runs the HBM2
    // device never finishes later than DDR4.
    forall("memtech_hbm2_streaming", 8, |rng| {
        let bursts = rng.range(64, 4_000) as u64;
        let chunk = [64usize, 256, 1024, 4096][rng.range(0, 4)];
        let base = rng.below(1 << 30);
        let run = |cfg: &MemTechConfig| {
            let mut dev = MemDevice::new(cfg);
            let mut t = 0;
            for i in 0..bursts {
                t = dev.access(base + i * chunk as u64, chunk, t);
            }
            dev.makespan()
        };
        let (d, h) = (run(&ddr), run(&hbm));
        assert!(
            h <= d,
            "hbm2 must stream >= ddr4: {h} vs {d} cycles for {bursts}x{chunk}B"
        );
    });
}

#[test]
fn osram_never_charges_activate_or_precharge() {
    // No row-buffer dynamics: whatever the access pattern, the
    // scratchpad's row counters stay 0 — it literally cannot charge an
    // activate (row miss/conflict) or precharge (conflict) cycle.
    forall("memtech_osram_no_row_dynamics", 10, |rng| {
        let cfg = MemTech::Osram.default_config();
        let mut dev = MemDevice::new(&cfg);
        let n = rng.range(1, 2_000);
        let mut t = 0;
        let mut moved = 0u64;
        for i in 0..n as u64 {
            let (addr, len) = match rng.below(3) {
                0 => (i * 64, 64usize),
                1 => (rng.below(1 << 28), 1 + rng.below(2_048) as usize),
                _ => (rng.below(1 << 12), 1usize),
            };
            let done = dev.access(addr, len, t);
            assert!(done >= t, "completion must not precede issue");
            t = done;
            moved += len as u64;
        }
        let s = dev.stats();
        assert_eq!(s.activations(), 0, "osram charged an activation");
        assert_eq!(s.row_hits, 0);
        assert_eq!(s.row_misses, 0);
        assert_eq!(s.row_conflicts, 0);
        assert!(s.bursts > 0 && s.bytes >= moved, "osram must move the bytes");
    });
}

#[test]
fn osram_default_config_has_no_row_knobs_in_its_latency() {
    // The analytic counterparts agree with "no row dynamics": a random
    // access costs exactly the flat latency plus one word occupancy,
    // independent of any row policy, and streaming runs at the
    // port-limited peak.
    let os = OsramConfig::default_16p();
    let cfg = MemTechConfig::Osram(os.clone());
    assert_eq!(
        cfg.random_access_cycles(),
        (os.t_access + os.t_word) as f64
    );
    assert_eq!(cfg.stream_bytes_per_cycle(), cfg.peak_bytes_per_cycle());
}

#[test]
fn hbm2_trait_instance_matches_its_flat_dram_equivalent() {
    // HBM2 composes over the shared DRAM engine driven by the
    // flattened pseudo-channel geometry; the device must be
    // bit-identical to a raw `Dram` built from `flat_dram()`.
    forall("memtech_hbm2_vs_flat_dram", 6, |rng| {
        let h = Hbm2Config::default_u280();
        let mut raw = Dram::new(h.flat_dram());
        let mut dev = MemDevice::new(&MemTechConfig::Hbm2(h));
        let n = rng.range(1, 1_000);
        let accs: Vec<(u64, usize)> = (0..n)
            .map(|_| (rng.below(1 << 30), 1 + rng.below(4_096) as usize))
            .collect();
        let (raw_cycles, raw_stats, raw_span) = replay(&mut raw, &accs);
        let (dev_cycles, dev_stats, dev_span) = replay(&mut dev, &accs);
        assert_eq!(raw_cycles, dev_cycles);
        assert_eq!(raw_stats, dev_stats);
        assert_eq!(raw_span, dev_span);
    });
}
