//! S24 out-of-core property suite: the bounded-memory pipeline must be
//! bit-identical to the in-RAM pipeline at every randomized boundary.
//!
//! * Block-streamed FROSTT ingestion ([`TnsBlockReader`]) vs the
//!   whole-file parser, at random block sizes with comments and blank
//!   lines straddling block boundaries.
//! * Windowed event replay ([`replay_events_source`]) vs one
//!   monolithic `replay_events`, on real Approach-1 traces at random
//!   window sizes.
//! * Windowed grid classification + replay
//!   ([`GridClassification::classify_source`] / `replay_source`) vs
//!   the monolithic entry points, full [`GridRun`] equality.
//! * Windowed timing-op extraction ([`TimingOps::extract_source`]) vs
//!   monolithic extraction, compared through `time_grid`.
//! * Shard planning from the one-pass coordinate-histogram sketch fed
//!   block by block vs [`ShardPlan::balance`] on the materialized
//!   tensor.
//! * The dedup-free streamed synthesizer vs [`generate`] on tensors
//!   sparse enough that the dedup path accepts every draw.

use ptmc::controller::{CacheConfig, ControllerConfig, MemLayout, MemoryController};
use ptmc::cpd::linalg::Mat;
use ptmc::engine::{
    replay_events_source, ChunkedWindows, CompressedTrace, GridClassification, TimingCandidate,
    TimingOps,
};
use ptmc::mttkrp::{approach1, Tracing};
use ptmc::shard::{CoordHistogram, ShardPlan};
use ptmc::tensor::frostt::{read_tns, write_tns, TnsBlockReader, TnsError};
use ptmc::tensor::synth::{generate, generate_streamed, Profile, SynthConfig};
use ptmc::tensor::{Coord, SortOrder, SparseTensor};
use ptmc::testkit::{forall, Rng};

fn assert_same_tensor(a: &SparseTensor, b: &SparseTensor) {
    assert_eq!(a.n_modes(), b.n_modes());
    assert_eq!(a.dims(), b.dims());
    assert_eq!(a.nnz(), b.nnz());
    assert_eq!(a.values(), b.values(), "values diverged");
    for m in 0..a.n_modes() {
        assert_eq!(a.mode_col(m), b.mode_col(m), "mode {m} columns diverged");
    }
}

/// A small random tensor and the real Approach-1 access trace of one
/// of its modes — the trace shape the streaming cores exist for.
fn approach1_trace(rng: &mut Rng) -> Vec<ptmc::controller::Access> {
    let dims = vec![rng.range(20, 60), rng.range(20, 60), rng.range(20, 60)];
    let mut t = generate(&SynthConfig {
        dims,
        nnz: rng.range(200, 1_200),
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed: rng.next_u64(),
    });
    let rank = 8;
    let factors: Vec<Mat> = t
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| Mat::randn(d, rank, m as u64))
        .collect();
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
    let mode = rng.range(0, 3);
    t.sort_by_mode(mode);
    approach1::run(&t, &factors, mode, &layout, Tracing::On).trace
}

#[test]
fn block_streamed_parse_matches_in_ram_parse() {
    forall("streamed_parse_equivalence", 24, |rng| {
        // Random tensor -> .tns text with comments / blank lines
        // interleaved so noise straddles block boundaries.
        let n_modes = rng.range(2, 5);
        let nnz = rng.range(1, 150);
        let mut cols: Vec<Vec<Coord>> = vec![Vec::with_capacity(nnz); n_modes];
        let mut vals: Vec<f32> = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            for col in cols.iter_mut() {
                col.push(rng.below(40) as Coord);
            }
            let mut v = (rng.f32() - 0.5) * 100.0;
            if v == 0.0 {
                v = 1.0;
            }
            vals.push(v);
        }
        let dims: Vec<usize> = cols
            .iter()
            .map(|c| *c.iter().max().unwrap() as usize + 1)
            .collect();
        let t = SparseTensor::from_columns(dims, cols, vals, SortOrder::Unsorted);
        let mut buf = Vec::new();
        write_tns(&t, &mut buf).unwrap();
        let mut noisy = String::new();
        for line in String::from_utf8(buf).unwrap().lines() {
            while rng.below(4) == 0 {
                noisy.push_str(if rng.below(2) == 0 { "# noise\n" } else { "\n" });
            }
            noisy.push_str(line);
            if rng.below(5) == 0 {
                noisy.push_str(" # trailing");
            }
            noisy.push('\n');
        }

        let whole = read_tns(noisy.as_bytes()).expect("in-RAM parse");
        let block_nnz = rng.range(1, 40);
        let mut r = TnsBlockReader::new(noisy.as_bytes(), block_nnz);
        let mut cols: Vec<Vec<Coord>> = Vec::new();
        let mut vals: Vec<f32> = Vec::new();
        while let Some(b) = r.next_block().expect("streamed parse") {
            assert!(b.nnz() <= block_nnz, "block overflowed");
            if cols.is_empty() {
                cols = b.cols;
                vals = b.vals;
            } else {
                for (c, mut bc) in cols.iter_mut().zip(b.cols) {
                    c.append(&mut bc);
                }
                vals.extend(b.vals);
            }
        }
        let streamed = SparseTensor::from_columns(r.dims(), cols, vals, SortOrder::Unsorted);
        assert_same_tensor(&whole, &streamed);
    });
}

#[test]
fn parse_errors_report_exact_line_numbers_across_block_boundaries() {
    // S31 satellite: a garbage line anywhere in the stream must fail
    // with the exact *physical* line number, no matter how comments,
    // blank lines, and block boundaries fall around it — and the
    // streamed reader must agree with the whole-file parser.
    forall("streamed_parse_exact_line_numbers", 24, |rng| {
        let nnz = rng.range(5, 60);
        let mut lines: Vec<String> = Vec::new();
        let mut data_linenos: Vec<usize> = Vec::new();
        for _ in 0..nnz {
            while rng.below(4) == 0 {
                lines.push(if rng.below(2) == 0 {
                    "# noise".to_string()
                } else {
                    String::new()
                });
            }
            data_linenos.push(lines.len() + 1);
            lines.push(format!(
                "{} {} {} {:.1}",
                1 + rng.below(40),
                1 + rng.below(40),
                1 + rng.below(40),
                (rng.f32() + 0.5) * 10.0
            ));
        }
        // Corrupt one random data entry (never the first, so the
        // reader has an established arity to violate).
        let victim = data_linenos[rng.range(1, data_linenos.len())];
        lines[victim - 1] = match rng.below(4) {
            0 => "x9 1 1 1.0".to_string(),  // garbage coordinate
            1 => "0 1 1 1.0".to_string(),   // 1-based violation
            2 => "1 1 1.0".to_string(),     // arity change
            _ => "1 1 1 1.2.3".to_string(), // garbage value
        };
        let text = lines.join("\n") + "\n";

        let whole = read_tns(text.as_bytes()).unwrap_err();
        let TnsError::Parse(whole_line, _) = whole else {
            panic!("whole-file parse must fail with Parse, got {whole}");
        };
        assert_eq!(whole_line, victim, "whole-file parser blamed the wrong line");

        let block_nnz = rng.range(1, 20);
        let mut r = TnsBlockReader::new(text.as_bytes(), block_nnz);
        let streamed = loop {
            match r.next_block() {
                Ok(Some(_)) => {}
                Ok(None) => panic!(
                    "stream with a corrupt line {victim} ended cleanly at block {block_nnz}"
                ),
                Err(e) => break e,
            }
        };
        let TnsError::Parse(stream_line, _) = streamed else {
            panic!("streamed parse must fail with Parse, got {streamed}");
        };
        assert_eq!(
            stream_line, victim,
            "streamed parser blamed the wrong line at block size {block_nnz}"
        );
    });
}

/// A reader that serves a prefix of a `.tns` stream and then fails
/// every further read — a dropped NFS mount / truncated pipe.
struct FailingReader<'a> {
    data: &'a [u8],
    at: usize,
}

impl std::io::Read for FailingReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.at >= self.data.len() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "stream died mid-file",
            ));
        }
        let n = buf.len().min(self.data.len() - self.at);
        buf[..n].copy_from_slice(&self.data[self.at..self.at + n]);
        self.at += n;
        Ok(n)
    }
}

#[test]
fn short_reads_surface_as_io_errors_not_silent_truncation() {
    // S31 satellite: when the underlying stream dies mid-file the
    // reader must return a typed IO error — never a clean end-of-file
    // that silently drops the tail of the tensor.
    forall("streamed_short_reads", 12, |rng| {
        let nnz = rng.range(4, 40);
        let mut text = String::new();
        for i in 0..nnz {
            text.push_str(&format!("{} {} {} 1.0\n", i + 1, 1 + rng.below(9), 1 + rng.below(9)));
        }
        // Cut somewhere strictly inside the data so entries remain
        // unread when the failure hits.
        let cut = rng.range(1, text.len());
        let block_nnz = rng.range(1, 8);
        let reader = std::io::BufReader::new(FailingReader {
            data: &text.as_bytes()[..cut],
            at: 0,
        });
        let mut r = TnsBlockReader::new(reader, block_nnz);
        let mut yielded = 0usize;
        let err = loop {
            match r.next_block() {
                Ok(Some(b)) => yielded += b.nnz(),
                Ok(None) => panic!(
                    "reader ended cleanly after {yielded}/{nnz} entries (cut {cut}): \
                     short read became silent truncation"
                ),
                Err(e) => break e,
            }
        };
        assert!(
            matches!(&err, TnsError::Io(e) if e.kind() == std::io::ErrorKind::ConnectionReset),
            "expected the stream's IO error, got {err}"
        );
        assert!(yielded < nnz, "every entry arrived yet the stream failed");

        // The whole-file parser refuses the same stream identically.
        let whole = read_tns(std::io::BufReader::new(FailingReader {
            data: &text.as_bytes()[..cut],
            at: 0,
        }))
        .unwrap_err();
        assert!(matches!(whole, TnsError::Io(_)), "got {whole}");
    });
}

#[test]
fn windowed_event_replay_matches_monolithic_on_real_traces() {
    forall("streamed_event_replay", 10, |rng| {
        let trace = approach1_trace(rng);
        let mono = CompressedTrace::compress(&trace);
        let window = rng.range(1, trace.len() + 1);
        let mut a = MemoryController::new(ControllerConfig::default_for(16));
        let mut b = MemoryController::new(ControllerConfig::default_for(16));
        let ta = a.replay_events(&mono);
        let tb = replay_events_source(&mut b, &mut ChunkedWindows::new(&trace, window));
        assert_eq!(ta, tb, "cycles diverged at window {window}");
        assert_eq!(a.stats(), b.stats(), "window {window}");
        assert_eq!(a.cache_stats(), b.cache_stats(), "window {window}");
        assert_eq!(a.dma_stats(), b.dma_stats(), "window {window}");
        assert_eq!(a.dram_stats(), b.dram_stats(), "window {window}");
    });
}

fn random_cache_grid(rng: &mut Rng) -> Vec<CacheConfig> {
    let mut grid = Vec::new();
    for _ in 0..rng.range(2, 6) {
        let assoc = 1usize << rng.range(0, 3);
        let num_lines = assoc.max(64) << rng.range(0, 4);
        grid.push(CacheConfig {
            line_bytes: 16usize << rng.range(0, 4),
            num_lines,
            assoc,
            hit_latency: rng.range(1, 4) as u64,
        });
    }
    grid
}

#[test]
fn windowed_grid_replay_matches_monolithic_on_real_traces() {
    forall("streamed_grid_replay", 8, |rng| {
        let trace = approach1_trace(rng);
        let mono_trace = CompressedTrace::compress(&trace);
        let grid = random_cache_grid(rng);
        let window = rng.range(1, trace.len() + 1);
        let mono = GridClassification::classify(&mono_trace, &grid);
        let cls = GridClassification::classify_source(&mut ChunkedWindows::new(&trace, window), &grid);
        for (i, cc) in grid.iter().enumerate() {
            let mut cfg = ControllerConfig::default_for(16);
            cfg.cache = *cc;
            let want = mono.replay(i, &mono_trace, &cfg);
            let got = cls.replay_source(i, &mut ChunkedWindows::new(&trace, window), &cfg);
            assert_eq!(got, want, "{cc:?} diverged at window {window}");
        }
    });
}

#[test]
fn windowed_timing_extraction_matches_monolithic_on_real_traces() {
    forall("streamed_timing_extraction", 8, |rng| {
        let trace = approach1_trace(rng);
        let mono_trace = CompressedTrace::compress(&trace);
        let cache = CacheConfig {
            line_bytes: 32,
            num_lines: 256,
            assoc: 2,
            hit_latency: 2,
        };
        let window = rng.range(1, trace.len() + 1);
        let mono_cls = GridClassification::classify(&mono_trace, &[cache]);
        let mono_ops = TimingOps::extract(&mono_cls, 0, &mono_trace);
        let cls =
            GridClassification::classify_source(&mut ChunkedWindows::new(&trace, window), &[cache]);
        let ops = TimingOps::extract_source(&cls, 0, &mut ChunkedWindows::new(&trace, window));
        // Time a few candidates through both op queues: identical
        // queues must produce identical runs.
        let mut cands = Vec::new();
        for _ in 0..3 {
            let mut cfg = ControllerConfig::default_for(16);
            cfg.dma.num_dmas = 1 << rng.range(0, 3);
            cfg.mem.ddr4_mut().channels = 1 << rng.range(0, 3);
            cands.push(TimingCandidate::of(&cfg));
        }
        assert_eq!(
            mono_ops.time_grid(&cands),
            ops.time_grid(&cands),
            "timing runs diverged at window {window}"
        );
    });
}

#[test]
fn histogram_sketch_plans_match_materialized_balance_block_by_block() {
    forall("streamed_shard_planning", 16, |rng| {
        let n_modes = rng.range(2, 5);
        let dims: Vec<usize> = (0..n_modes).map(|_| rng.range(10, 200)).collect();
        let t = generate(&SynthConfig {
            dims: dims.clone(),
            nnz: rng.range(50, 2_000).min(
                dims.iter().product::<usize>() / 2,
            ),
            profile: Profile::Uniform,
            seed: rng.next_u64(),
        });
        // Feed the sketch in bounded blocks, as streamed ingestion would.
        let block = rng.range(1, t.nnz() + 1);
        let mut hist = CoordHistogram::new();
        let mut at = 0;
        while at < t.nnz() {
            let hi = (at + block).min(t.nnz());
            let cols: Vec<Vec<Coord>> = (0..n_modes)
                .map(|m| t.mode_col(m)[at..hi].to_vec())
                .collect();
            hist.observe(&cols);
            at = hi;
        }
        let k = rng.range(1, 9);
        for mode in 0..n_modes {
            let want = ShardPlan::balance(&t, mode, k);
            let got = hist.plan_for_dim(mode, t.dims()[mode], k);
            assert_eq!(got.mode, want.mode);
            assert_eq!(
                got.shards, want.shards,
                "mode {mode} k {k} block {block} diverged"
            );
        }
    });
}

#[test]
fn streamed_synthesis_matches_dedup_synthesis_when_sparse() {
    forall("streamed_synthesis_equivalence", 12, |rng| {
        // Space >= 1e9, nnz <= 1000: the dedup generator accepts every
        // draw, so both must walk the identical RNG sequence.
        let cfg = SynthConfig {
            dims: vec![1_000, 1_000, 1_000],
            nnz: rng.range(1, 1_000),
            profile: if rng.below(2) == 0 {
                Profile::Uniform
            } else {
                Profile::Zipf { alpha_milli: 1200 }
            },
            seed: rng.next_u64(),
        };
        assert_same_tensor(&generate(&cfg), &generate_streamed(&cfg));
    });
}
