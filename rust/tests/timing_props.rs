//! Property tests for the vectorized timing core
//! (`ptmc::engine::timing`): on randomized tensors, shard traces, and
//! adversarial access mixes, one classification + op-queue extraction
//! followed by a single multi-lane walk must produce, for **every**
//! DRAM/DMA candidate of the full default DSE grids, exactly the
//! completion cycles and statistics a fresh per-candidate event replay
//! of the same trace produces — the timing-dimension counterpart of
//! `grid_props.rs`.

use ptmc::controller::{Access, ControllerConfig, MemLayout, MemoryController};
use ptmc::dram::RowPolicy;
use ptmc::dse::Grids;
use ptmc::engine::{EngineKind, GridClassification, PreparedTrace, TimingCandidate, TimingOps};
use ptmc::shard::{partition_indices, shard_trace, ShardPlan};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::tensor::SparseTensor;
use ptmc::testkit::{forall, Rng};

/// A random synthetic tensor: 3 or 4 modes, varying nnz and skew.
fn random_tensor(rng: &mut Rng) -> SparseTensor {
    let n_modes = rng.range(3, 5);
    let dims: Vec<usize> = (0..n_modes).map(|_| rng.range(30, 300)).collect();
    let space: usize = dims.iter().product();
    let nnz = rng.range(1, 1_500).min(space / 4).max(1);
    let profile = match rng.below(3) {
        0 => Profile::Uniform,
        1 => Profile::Zipf {
            alpha_milli: 1_050 + rng.below(500) as u32,
        },
        _ => Profile::Clustered {
            block: 8,
            blocks: 20,
        },
    };
    generate(&SynthConfig {
        dims,
        nnz,
        profile,
        seed: rng.next_u64(),
    })
}

/// Every DRAM/DMA candidate of the **full default DSE grids**: the
/// cross product `Grids::default()` sweeps in the DMA and DRAM modules,
/// folded into one lane list (DMA grid at base DRAM + DRAM grid at
/// base DMA — exactly the candidates `explore` scores).
fn default_timing_grid(base: &ControllerConfig) -> Vec<TimingCandidate> {
    let g = Grids::default();
    let mut cands = Vec::new();
    for &num_dmas in &g.dma_num {
        for &buffers_per_dma in &g.dma_buffers {
            for &buffer_bytes in &g.dma_buffer_bytes {
                let mut cfg = base.clone();
                cfg.dma.num_dmas = num_dmas;
                cfg.dma.buffers_per_dma = buffers_per_dma;
                cfg.dma.buffer_bytes = buffer_bytes;
                cands.push(TimingCandidate::of(&cfg));
            }
        }
    }
    for &channels in &g.dram_channels {
        for &banks in &g.dram_banks {
            for &row_policy in &g.dram_row_policy {
                let mut cfg = base.clone();
                {
                    let dram = cfg.mem.ddr4_mut();
                    dram.channels = channels;
                    dram.banks = banks;
                    dram.row_policy = row_policy;
                }
                cands.push(TimingCandidate::of(&cfg));
            }
        }
    }
    cands
}

/// Assert: timing the whole candidate grid from one extracted op queue
/// equals a fresh per-candidate event replay, in cycles and every
/// statistics counter.
fn assert_timing_grid_identical(prepared: &PreparedTrace, base: &ControllerConfig, what: &str) {
    let cands = default_timing_grid(base);
    let cls = GridClassification::classify(prepared.compressed(), &[base.cache]);
    let ops = TimingOps::extract(&cls, 0, prepared.compressed());
    let runs = ops.time_grid(&cands);
    assert_eq!(runs.len(), cands.len());
    for (cand, run) in cands.iter().zip(&runs) {
        let mut cfg = base.clone();
        cfg.mem = cand.mem.clone();
        cfg.dma = cand.dma;
        let mut ctl = MemoryController::new(cfg);
        let want = EngineKind::Event.replay(&mut ctl, prepared);
        assert_eq!(run.cycles, want, "{what}: cycles diverged for {cand:?}");
        assert_eq!(
            run.stats,
            *ctl.stats(),
            "{what}: ControllerStats diverged for {cand:?}"
        );
        assert_eq!(
            run.cache,
            *ctl.cache_stats(),
            "{what}: CacheStats diverged for {cand:?}"
        );
        assert_eq!(
            run.dma,
            *ctl.dma_stats(),
            "{what}: DmaStats diverged for {cand:?}"
        );
        assert_eq!(
            run.dram,
            *ctl.dram_stats(),
            "{what}: DramStats diverged for {cand:?}"
        );
    }
    // The chunked-parallel walk is the same computation on lane
    // subsets; it must not change a single cycle.
    assert_eq!(runs, ops.time_grid_parallel(&cands), "{what}: parallel walk diverged");
}

#[test]
fn timing_core_is_bit_identical_on_shard_traces() {
    forall("timing_grid_vs_event_shard_traces", 6, |rng| {
        let t = random_tensor(rng);
        let rank = [4usize, 8, 16][rng.range(0, 3)];
        let mode = rng.range(0, t.n_modes());
        let workers = rng.range(1, 4);
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let plan = ShardPlan::balance(&t, mode, workers);
        let parts = partition_indices(&t, &plan);
        let mut base = ControllerConfig::default_for(t.record_bytes());
        // Vary the classified cache too: the op queue must be exact for
        // any cache candidate, not just the default.
        base.cache.num_lines = [64usize, 1024][rng.range(0, 2)];
        base.cache.assoc = [1usize, 4][rng.range(0, 2)];
        let mut offset = 0usize;
        for (spec, zs) in plan.shards.iter().zip(&parts) {
            let trace = shard_trace(&t, rank, mode, &layout, spec, zs, offset);
            offset += spec.nnz;
            let prepared = PreparedTrace::new(trace);
            assert_timing_grid_identical(&prepared, &base, "shard trace");
        }
    });
}

#[test]
fn timing_core_is_bit_identical_on_adversarial_access_mixes() {
    // Cold classes, unaligned addresses, width changes, and far-apart
    // cached addresses exercise the verbatim-run path of the op
    // extraction.
    forall("timing_grid_vs_event_adversarial", 8, |rng| {
        let n = rng.range(1, 500);
        let mut trace = Vec::with_capacity(n);
        for i in 0..n as u64 {
            let a = match rng.below(8) {
                0 => Access::Stream {
                    addr: i * 4096,
                    bytes: 4096,
                },
                1 => Access::Stream {
                    addr: rng.below(1 << 30),
                    bytes: 1 + rng.below(8192) as usize,
                },
                2 => Access::Cached {
                    addr: (8 << 20) + rng.below(1 << 14) * 64,
                    bytes: 64,
                },
                3 => Access::Cached {
                    addr: rng.below(1 << 26),
                    bytes: 1 + rng.below(256) as usize,
                },
                4 => Access::Cached {
                    addr: (1 << 40) + rng.below(1 << 20) * 64,
                    bytes: 64,
                },
                5 => Access::Element {
                    addr: rng.below(1 << 32),
                    bytes: 16,
                },
                6 => Access::CachedStore {
                    addr: rng.below(1 << 24) * 16,
                    bytes: 16,
                },
                _ => Access::Stream {
                    addr: (2 << 30) + (i % 7) * 64,
                    bytes: 64,
                },
            };
            trace.push(a);
        }
        let prepared = PreparedTrace::new(trace);
        let base = ControllerConfig::default_for(16);
        assert_timing_grid_identical(&prepared, &base, "adversarial trace");
    });
}

#[test]
fn op_queue_is_reusable_across_walks() {
    // Timing is a pure function of (ops, candidates): walking the same
    // queue twice, or in a different candidate order, changes nothing.
    forall("timing_ops_reusable", 4, |rng| {
        let t = random_tensor(rng);
        let rank = 8;
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let plan = ShardPlan::balance(&t, 0, 2);
        let parts = partition_indices(&t, &plan);
        let trace = shard_trace(&t, rank, 0, &layout, &plan.shards[0], &parts[0], 0);
        let prepared = PreparedTrace::new(trace);
        let base = ControllerConfig::default_for(t.record_bytes());
        let cls = GridClassification::classify(prepared.compressed(), &[base.cache]);
        let ops = TimingOps::extract(&cls, 0, prepared.compressed());
        let mut cands = default_timing_grid(&base);
        let first = ops.time_grid(&cands);
        assert_eq!(first, ops.time_grid(&cands), "second walk diverged");
        cands.reverse();
        let reversed = ops.time_grid(&cands);
        for (i, run) in reversed.iter().enumerate() {
            assert_eq!(*run, first[first.len() - 1 - i], "order dependence");
        }
    });
}

#[test]
fn closed_policy_lanes_report_activate_only_traffic() {
    // Sanity on the new DRAM knob through the timing core: a closed-
    // page lane must report zero row hits and zero conflicts while
    // moving the same bytes as its open-page twin.
    let t = generate(&SynthConfig {
        dims: vec![300, 200, 150],
        nnz: 4_000,
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed: 11,
    });
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
    let plan = ShardPlan::balance(&t, 0, 1);
    let parts = partition_indices(&t, &plan);
    let trace = shard_trace(&t, 8, 0, &layout, &plan.shards[0], &parts[0], 0);
    let prepared = PreparedTrace::new(trace);
    let base = ControllerConfig::default_for(t.record_bytes());
    let cls = GridClassification::classify(prepared.compressed(), &[base.cache]);
    let ops = TimingOps::extract(&cls, 0, prepared.compressed());
    let mut closed = base.clone();
    closed.mem.ddr4_mut().row_policy = RowPolicy::Closed;
    let runs = ops.time_grid(&[TimingCandidate::of(&base), TimingCandidate::of(&closed)]);
    assert_eq!(runs[1].dram.row_hits, 0);
    assert_eq!(runs[1].dram.row_conflicts, 0);
    assert_eq!(runs[1].dram.row_misses, runs[1].dram.bursts);
    assert_eq!(runs[0].dram.bytes, runs[1].dram.bytes);
    assert!(runs[0].dram.row_hits > 0, "open page must hit on streams");
}
