//! Property tests for the shard planner's invariants: every plan is an
//! output-disjoint exact cover (each nnz assigned to exactly one shard,
//! each coordinate owned by exactly one contiguous range), conserves
//! the nnz count, and respects the greedy balance bound — across worker
//! counts 1–8, including degenerate plans with more workers than
//! distinct output coordinates.

use ptmc::shard::{partition_indices, ShardPlan};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::tensor::SparseTensor;
use ptmc::testkit::{forall, Rng};

fn random_tensor(rng: &mut Rng) -> SparseTensor {
    let n_modes = rng.range(3, 5);
    let dims: Vec<usize> = (0..n_modes).map(|_| rng.range(3, 200)).collect();
    let space: usize = dims.iter().product();
    let nnz = rng.range(1, 4_000).min(space / 3).max(1);
    let profile = if rng.below(2) == 0 {
        Profile::Uniform
    } else {
        Profile::Zipf {
            alpha_milli: 1_050 + rng.below(600) as u32,
        }
    };
    generate(&SynthConfig {
        dims,
        nnz,
        profile,
        seed: rng.next_u64(),
    })
}

/// Cover + disjointness + conservation, phrased on the plan alone.
fn assert_plan_invariants(plan: &ShardPlan, mode_len: usize, total_nnz: usize, k: usize) {
    assert_eq!(plan.k(), k, "plan must have exactly k shards");
    let mut expect_lo = 0u32;
    for s in &plan.shards {
        assert_eq!(s.coord_lo, expect_lo, "ranges must tile contiguously");
        assert!(s.coord_lo <= s.coord_hi, "ranges must be non-negative");
        expect_lo = s.coord_hi;
    }
    assert_eq!(
        expect_lo as usize, mode_len,
        "ranges must cover the whole coordinate axis"
    );
    assert_eq!(plan.total_nnz(), total_nnz, "nnz must be conserved");
}

#[test]
fn plans_are_output_disjoint_exact_covers_for_1_to_8_workers() {
    forall("shard_plan_cover_k1_8", 16, |rng| {
        let t = random_tensor(rng);
        let mode = rng.range(0, t.n_modes());
        for k in 1..=8usize {
            let plan = ShardPlan::balance(&t, mode, k);
            assert_plan_invariants(&plan, t.dims()[mode], t.nnz(), k);

            // Every nnz lands in exactly one shard, inside its range.
            let parts = partition_indices(&t, &plan);
            let mut seen = vec![false; t.nnz()];
            for (sid, zs) in parts.iter().enumerate() {
                assert_eq!(zs.len(), plan.shards[sid].nnz, "partition/plan nnz mismatch");
                for &z in zs {
                    assert!(!seen[z], "nnz {z} assigned twice");
                    seen[z] = true;
                    let c = t.mode_col(mode)[z];
                    assert_eq!(plan.shard_of(c), sid, "owner lookup disagrees");
                }
            }
            assert!(seen.iter().all(|&s| s), "some nnz unassigned");
        }
    });
}

#[test]
fn balance_bound_holds_for_random_histograms() {
    // Greedy prefix partition bound: no shard exceeds its proportional
    // share by more than one un-splittable fiber — max_shard_nnz <=
    // floor(total/k) + max_fiber.  (A coordinate is never split, so the
    // heaviest fiber is the irreducible overshoot.)
    forall("shard_balance_bound", 48, |rng| {
        let n_coords = rng.range(1, 400);
        let counts: Vec<usize> = (0..n_coords)
            .map(|_| {
                if rng.below(10) == 0 {
                    rng.range(0, 5_000) // occasional hot fiber
                } else {
                    rng.range(0, 40)
                }
            })
            .collect();
        let total: usize = counts.iter().sum();
        let max_fiber = counts.iter().copied().max().unwrap_or(0);
        for k in 1..=8usize {
            let plan = ShardPlan::from_counts(0, &counts, k);
            assert_plan_invariants(&plan, n_coords, total, k);
            let heaviest = plan.shards.iter().map(|s| s.nnz).max().unwrap_or(0);
            assert!(
                heaviest <= total / k + max_fiber,
                "k={k}: heaviest shard {heaviest} exceeds {}/{k} + {max_fiber}",
                total
            );
        }
    });
}

#[test]
fn more_workers_than_distinct_coordinates_degrades_gracefully() {
    forall("shard_plan_tiny_axes", 32, |rng| {
        // Axes with very few (possibly zero-count) coordinates, k up
        // to 8 — far more workers than distinct output coordinates.
        let n_coords = rng.range(1, 6);
        let counts: Vec<usize> = (0..n_coords).map(|_| rng.range(0, 50)).collect();
        let total: usize = counts.iter().sum();
        let distinct = counts.iter().filter(|&&c| c > 0).count();
        for k in 1..=8usize {
            let plan = ShardPlan::from_counts(1, &counts, k);
            assert_plan_invariants(&plan, n_coords, total, k);
            let nonempty = plan.shards.iter().filter(|s| s.nnz > 0).count();
            assert!(
                nonempty <= distinct.max(1),
                "k={k}: {nonempty} non-empty shards for {distinct} used coords"
            );
            // Ranges with rows own their coordinates exclusively.
            for (sid, s) in plan.shards.iter().enumerate() {
                if s.rows() > 0 {
                    assert_eq!(plan.shard_of(s.coord_lo), sid);
                    assert_eq!(plan.shard_of(s.coord_hi - 1), sid);
                }
            }
        }
    });
}

#[test]
fn imbalance_is_bounded_and_exact_on_known_histograms() {
    // imbalance = heaviest / (total/k): 1.0 means perfect balance, k
    // means everything on one shard; both extremes must be reachable.
    let uniform = vec![10usize; 64];
    let plan = ShardPlan::from_counts(0, &uniform, 4);
    assert!((plan.imbalance() - 1.0).abs() < 1e-9, "{}", plan.imbalance());

    let mut hot = vec![0usize; 64];
    hot[17] = 1_000;
    let plan = ShardPlan::from_counts(0, &hot, 4);
    assert!((plan.imbalance() - 4.0).abs() < 1e-9, "{}", plan.imbalance());

    forall("shard_imbalance_range", 24, |rng| {
        let counts: Vec<usize> = (0..rng.range(1, 200)).map(|_| rng.below(100) as usize).collect();
        let total: usize = counts.iter().sum();
        for k in 1..=8usize {
            let plan = ShardPlan::from_counts(0, &counts, k);
            let imb = plan.imbalance();
            if total > 0 {
                assert!(imb >= 1.0 - 1e-9, "imbalance {imb} below 1");
                assert!(imb <= k as f64 + 1e-9, "imbalance {imb} above k={k}");
            } else {
                assert_eq!(imb, 1.0, "empty histogram is trivially balanced");
            }
        }
    });
}
