//! Property tests for the FROSTT `.tns` reader/writer: write→read
//! identity, 1-based coordinate handling, tolerance for comments and
//! blank lines, and exact `Parse` line numbers on malformed input.

use ptmc::tensor::frostt::{read_tns, write_tns, TnsError};
use ptmc::tensor::{Coord, SparseTensor};
use ptmc::testkit::{forall, Rng};

/// Random tensor whose dims equal the per-mode coordinate maxima + 1 —
/// the exact shape `.tns` reconstructs (the format stores no dims).
fn tight_random_tensor(rng: &mut Rng) -> SparseTensor {
    let n_modes = rng.range(2, 6);
    let nnz = rng.range(1, 200);
    let mut cols: Vec<Vec<Coord>> = vec![Vec::with_capacity(nnz); n_modes];
    let mut vals: Vec<f32> = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        for col in cols.iter_mut() {
            col.push(rng.below(50) as Coord);
        }
        let mut v = (rng.f32() - 0.5) * 200.0;
        if v == 0.0 {
            v = 1.0;
        }
        vals.push(v);
    }
    let dims: Vec<usize> = cols
        .iter()
        .map(|col| *col.iter().max().unwrap() as usize + 1)
        .collect();
    SparseTensor::from_columns(dims, cols, vals, ptmc::tensor::SortOrder::Unsorted)
}

fn assert_same_tensor(a: &SparseTensor, b: &SparseTensor) {
    assert_eq!(a.n_modes(), b.n_modes());
    assert_eq!(a.dims(), b.dims());
    assert_eq!(a.nnz(), b.nnz());
    assert_eq!(a.values(), b.values(), "values must round-trip exactly");
    for m in 0..a.n_modes() {
        assert_eq!(a.mode_col(m), b.mode_col(m), "mode {m} columns diverged");
    }
}

#[test]
fn write_read_is_the_identity() {
    forall("tns_write_read_identity", 32, |rng| {
        let t = tight_random_tensor(rng);
        let mut buf = Vec::new();
        write_tns(&t, &mut buf).expect("write to memory");
        let back = read_tns(&buf[..]).expect("read own output");
        assert_same_tensor(&t, &back);
    });
}

#[test]
fn written_coordinates_are_1_based() {
    forall("tns_one_based_output", 16, |rng| {
        let t = tight_random_tensor(rng);
        let mut buf = Vec::new();
        write_tns(&t, &mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        for (z, line) in text.lines().enumerate() {
            let fields: Vec<&str> = line.split_whitespace().collect();
            assert_eq!(fields.len(), t.n_modes() + 1);
            for (m, f) in fields[..t.n_modes()].iter().enumerate() {
                let c: u64 = f.parse().expect("integer coordinate");
                assert!(c >= 1, "coordinate must be 1-based");
                assert_eq!(c, t.mode_col(m)[z] as u64 + 1, "off-by-one in writer");
            }
        }
    });
}

#[test]
fn comments_and_blank_lines_are_tolerated_anywhere() {
    forall("tns_comment_blank_tolerance", 24, |rng| {
        let t = tight_random_tensor(rng);
        let mut buf = Vec::new();
        write_tns(&t, &mut buf).unwrap();
        let clean = String::from_utf8(buf).unwrap();

        // Re-assemble with random noise lines interleaved and random
        // trailing comments appended to data lines.
        let mut noisy = String::new();
        for line in clean.lines() {
            while rng.below(3) == 0 {
                match rng.below(3) {
                    0 => noisy.push_str("# a comment line\n"),
                    1 => noisy.push('\n'),
                    _ => noisy.push_str("   \n"),
                }
            }
            noisy.push_str(line);
            if rng.below(4) == 0 {
                noisy.push_str(" # trailing comment");
            }
            noisy.push('\n');
        }
        while rng.below(2) == 0 {
            noisy.push_str("# trailing file comment\n");
        }

        let back = read_tns(noisy.as_bytes()).expect("noisy file must parse");
        assert_same_tensor(&t, &back);
    });
}

#[test]
fn parse_errors_carry_the_exact_line_number() {
    forall("tns_parse_error_line_numbers", 32, |rng| {
        let t = tight_random_tensor(rng);
        let mut buf = Vec::new();
        write_tns(&t, &mut buf).unwrap();
        let clean = String::from_utf8(buf).unwrap();

        // Keep a random prefix of valid lines (plus comment padding so
        // physical line numbers differ from data-line counts), then
        // append one malformed line.
        let keep = rng.range(0, t.nnz().min(20) + 1);
        let mut text = String::new();
        let mut physical_lines = 0usize;
        for line in clean.lines().take(keep) {
            if rng.below(3) == 0 {
                text.push_str("# padding\n");
                physical_lines += 1;
            }
            text.push_str(line);
            text.push('\n');
            physical_lines += 1;
        }
        let arity = t.n_modes();
        let bad_line = match rng.below(4) {
            // 0-based coordinate.
            0 => format!("0{}", " 1".repeat(arity - 1) + " 1.0"),
            // Garbage value.
            1 => format!("{}abc", "1 ".repeat(arity)),
            // Wrong arity (only an error when a first line fixed it).
            2 if keep > 0 => format!("{}1.0", "1 ".repeat(arity + 1)),
            // Too few fields.
            _ => "1 1".to_string(),
        };
        text.push_str(&bad_line);
        text.push('\n');

        let err = read_tns(text.as_bytes()).expect_err("malformed line must fail");
        match err {
            TnsError::Parse(line, msg) => {
                assert_eq!(
                    line,
                    physical_lines + 1,
                    "wrong line number for {bad_line:?}: {msg}"
                );
            }
            other => panic!("expected Parse error, got {other}"),
        }
    });
}

#[test]
fn empty_and_comment_only_files_are_rejected_as_empty() {
    assert!(matches!(read_tns("".as_bytes()).unwrap_err(), TnsError::Empty));
    assert!(matches!(
        read_tns("# only\n\n# comments\n".as_bytes()).unwrap_err(),
        TnsError::Empty
    ));
}
