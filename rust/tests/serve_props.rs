//! Properties of the DSE service (S32): malformed frames answer typed
//! parse errors (never hangs), tenant budgets reject with
//! [`ErrorClass::Budget`], concurrent same-tensor clients receive
//! Pareto frontiers byte-identical to a solo cold run, repeat
//! submissions are pure memo hits, and a connection dropped mid-job
//! (the `serve.frame` failpoint) poisons neither the job queue nor the
//! cross-query memo.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

use ptmc::dse::SearchStrategy;
use ptmc::engine::EngineKind;
use ptmc::error::ErrorClass;
use ptmc::serve::client;
use ptmc::serve::proto::{self, EvalKind, GridPreset, JobSpec, Response};
use ptmc::serve::{ServeConfig, Server};
use ptmc::tensor::synth::Profile;
use ptmc::util::{fault, read_frame, write_frame};

/// Every server in this binary hits the same process-wide failpoint
/// sites and parallelism cap, so server-booting tests run one at a
/// time.
static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// Boot a server on a free port; returns its address and the join
/// handle of the accept loop.
fn boot(cfg: ServeConfig) -> (String, std::thread::JoinHandle<std::io::Result<()>>) {
    let server = Server::bind("127.0.0.1:0", cfg).expect("bind");
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run());
    (addr, handle)
}

/// A tiny cycle-sim job over the smoke grid — heavy enough to exercise
/// classification + simulation, small enough for test time.
fn sim_job(id: u64, tenant: &str, seed: u64) -> JobSpec {
    JobSpec {
        id,
        tenant: tenant.to_string(),
        dims: vec![64, 48, 32],
        nnz: 2_000,
        seed,
        profile: Profile::Zipf { alpha_milli: 1200 },
        rank: 4,
        evaluator: EvalKind::Sim,
        engine: EngineKind::Event,
        strategy: SearchStrategy::Coordinate,
        top_k: 1,
        grid: GridPreset::Smoke,
    }
}

/// Same workload through the fast analytic evaluator, for tests where
/// the exploration itself is incidental.
fn pms_job(id: u64, tenant: &str) -> JobSpec {
    JobSpec {
        evaluator: EvalKind::Pms,
        ..sim_job(id, tenant, 7)
    }
}

fn shutdown_and_join(
    addr: &str,
    handle: std::thread::JoinHandle<std::io::Result<()>>,
) {
    client::shutdown(addr).expect("shutdown");
    handle.join().expect("server thread").expect("server run");
}

#[test]
fn malformed_frames_get_typed_parse_errors_not_hangs() {
    let _guard = lock();
    let (addr, handle) = boot(ServeConfig {
        workers: 1,
        ..ServeConfig::default()
    });

    // (a) A well-framed body that is not a protocol message.
    {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write_frame(&mut s, b"this is not a ptmc frame").expect("write");
        let body = read_frame(&mut s, proto::MAX_FRAME)
            .expect("read")
            .expect("response frame");
        match Response::decode(&body).expect("decode") {
            Response::Error { id, class, .. } => {
                assert_eq!(id, 0);
                assert_eq!(class, ErrorClass::Parse);
            }
            other => panic!("expected parse error, got {other:?}"),
        }
        // The server closes a desynced connection after answering.
        assert!(read_frame(&mut s, proto::MAX_FRAME).expect("eof").is_none());
    }

    // (b) A hostile length prefix (4 GiB claim) is refused before
    // allocation, with a typed error.
    {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).expect("write prefix");
        s.flush().unwrap();
        let body = read_frame(&mut s, proto::MAX_FRAME)
            .expect("read")
            .expect("response frame");
        match Response::decode(&body).expect("decode") {
            Response::Error { class, .. } => assert_eq!(class, ErrorClass::Parse),
            other => panic!("expected parse error, got {other:?}"),
        }
    }

    // (c) A frame truncated mid-body (client dies mid-write): the
    // server must close, not hang.
    {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(&100u32.to_le_bytes()).expect("write prefix");
        s.write_all(b"only a few bytes").expect("write partial");
        s.shutdown(std::net::Shutdown::Write).expect("half-close");
        let body = read_frame(&mut s, proto::MAX_FRAME)
            .expect("read")
            .expect("response frame");
        match Response::decode(&body).expect("decode") {
            Response::Error { class, .. } => assert_eq!(class, ErrorClass::Parse),
            other => panic!("expected parse error, got {other:?}"),
        }
        assert!(read_frame(&mut s, proto::MAX_FRAME).expect("eof").is_none());
    }

    // The server survived all three abusive connections.
    let st = client::stats(&addr).expect("stats after abuse");
    assert_eq!(st.jobs_done, 0);
    shutdown_and_join(&addr, handle);
}

#[test]
fn tenant_budget_exhaustion_is_a_typed_budget_error() {
    let _guard = lock();
    let (addr, handle) = boot(ServeConfig {
        workers: 1,
        tenant_budget: Some(2),
        ..ServeConfig::default()
    });

    // Three jobs from one tenant against a budget of two.
    let jobs: Vec<JobSpec> = (1..=3).map(|i| pms_job(i, "greedy")).collect();
    let report = client::submit_batch(&addr, &jobs).expect("batch");
    assert_eq!(report.results.len(), 2, "two jobs within budget succeed");
    assert_eq!(report.errors.len(), 1, "the third is rejected");
    let err = &report.errors[0];
    assert_eq!(err.id, 3);
    assert_eq!(err.class, ErrorClass::Budget);
    assert_eq!(err.class.exit_code(), 5);
    assert_eq!(
        report.first_error_class(),
        Some(ErrorClass::Budget),
        "a CLI frontend exits with the budget class"
    );

    // Another tenant is unaffected.
    let other = client::submit_batch(&addr, &[pms_job(9, "frugal")]).expect("batch");
    assert_eq!(other.results.len(), 1);
    assert!(other.errors.is_empty());

    shutdown_and_join(&addr, handle);
}

#[test]
fn concurrent_same_tensor_clients_match_a_solo_cold_run() {
    let _guard = lock();

    // Baseline: one job on a fresh server — a solo cold run.
    let (addr, handle) = boot(ServeConfig {
        workers: 2,
        ..ServeConfig::default()
    });
    let solo = client::submit_batch(&addr, &[sim_job(1, "solo", 42)]).expect("solo");
    assert!(solo.errors.is_empty());
    let baseline = &solo.results[0];
    assert_eq!(baseline.memo_hits, 0, "a cold run has nothing to hit");
    shutdown_and_join(&addr, handle);

    // Fresh server, two clients racing the same tensor.
    let (addr, handle) = boot(ServeConfig {
        workers: 2,
        ..ServeConfig::default()
    });
    let reports: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|c| {
                let addr = addr.clone();
                scope.spawn(move || {
                    client::submit_batch(&addr, &[sim_job(c + 1, "racer", 42)])
                        .expect("concurrent batch")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut total_hits = 0;
    for report in &reports {
        assert!(report.errors.is_empty());
        let res = &report.results[0];
        assert_eq!(
            res.best.cycles_bits, baseline.best.cycles_bits,
            "winner diverged from the solo cold run"
        );
        assert_eq!(
            res.pareto, baseline.pareto,
            "Pareto frontier not byte-identical to the solo cold run"
        );
        total_hits += res.memo_hits;
    }
    // The two queries shared work through the memo: at least one of
    // them hit verdicts the other recorded.  (How many depends on the
    // race; sharing itself is guaranteed once one candidate finishes
    // before the other query reaches it.)
    let _ = total_hits; // racy lower bounds are asserted in the repeat test

    shutdown_and_join(&addr, handle);
}

#[test]
fn repeat_submission_is_pure_memo_hits() {
    let _guard = lock();
    let (addr, handle) = boot(ServeConfig {
        workers: 2,
        ..ServeConfig::default()
    });

    let cold = client::submit_batch(&addr, &[sim_job(1, "t", 5)]).expect("cold");
    assert!(cold.errors.is_empty());
    let cold_res = &cold.results[0];
    assert_eq!(cold_res.memo_hits, 0);
    assert!(cold_res.memo_misses > 0);

    let warm = client::submit_batch(&addr, &[sim_job(2, "t", 5)]).expect("repeat");
    assert!(warm.errors.is_empty());
    let warm_res = &warm.results[0];
    assert_eq!(
        warm_res.memo_misses, 0,
        "a repeat query must perform zero new simulations"
    );
    assert!(warm_res.memo_hits > 0, "repeat query reported no hits");
    assert_eq!(warm_res.best.cycles_bits, cold_res.best.cycles_bits);
    assert_eq!(warm_res.pareto, cold_res.pareto, "repeat frontier diverged");
    assert_eq!(warm_res.visited, cold_res.visited);
    assert_eq!(warm_res.rejected, cold_res.rejected);

    let st = client::stats(&addr).expect("stats");
    assert_eq!(st.jobs_done, 2);
    assert!(st.memo_entries > 0);
    assert!(st.memo_hits >= warm_res.memo_hits);

    shutdown_and_join(&addr, handle);
}

#[test]
fn dropped_connection_mid_job_poisons_neither_queue_nor_memo() {
    let _guard = lock();
    // The 2nd serve.frame check (the read after the first job is
    // queued) fails once: the server drops that connection as if the
    // client vanished mid-conversation.
    let fault_guard = fault::arm("serve.frame@2:brokenpipe").expect("arm plan");
    let (addr, handle) = boot(ServeConfig {
        workers: 1,
        ..ServeConfig::default()
    });

    // Client 1 submits one job, then loses its connection.  Depending
    // on how the race between the drop and the job's completion falls,
    // it sees either an IO error or (rarely) its result — both fine;
    // what matters is what the *next* client observes.
    let _ = client::submit_batch(&addr, &[sim_job(1, "dropped", 11)]);
    assert!(fault::hit_count(fault::SERVE_FRAME) >= 2);

    // Client 2 repeats the same tensor: the dropped client's job must
    // have completed into the shared memo (workers = 1 serializes the
    // queue), and the queue must still be serving.
    let report = client::submit_batch(&addr, &[sim_job(2, "survivor", 11)]).expect("batch");
    assert!(report.errors.is_empty(), "queue poisoned: {:?}", report.errors);
    let res = &report.results[0];
    assert_eq!(
        res.memo_misses, 0,
        "memo poisoned: the dropped client's verdicts are missing"
    );
    assert!(res.memo_hits > 0);

    drop(fault_guard);
    shutdown_and_join(&addr, handle);
}
