//! Property suite for the hierarchical joint sweep core
//! (`ptmc::engine::sweep`): on a seeded corpus of random tensors,
//! shard traces, and adversarial access mixes, scoring a subsampled
//! `cache × DRAM × DMA × remapper` joint cross product through
//! `JointIndex::sweep` must be **bit-identical** to a fresh
//! per-candidate event replay of the same trace; the sharded joint
//! path (`ShardedSweep::makespans_for_joint_grid`) must reproduce
//! `makespan_with` exactly, remap phase included; and the joint search
//! strategy must never report a worse winner than coordinate descent.

use ptmc::controller::{Access, ControllerConfig, MemLayout, MemoryController};
use ptmc::cpd::linalg::Mat;
use ptmc::dram::RowPolicy;
use ptmc::dse::{explore, explore_with, EvaluatorBuilder, Grids, SearchOptions, SearchStrategy};
use ptmc::engine::{EngineKind, JointIndex, PreparedTrace, TimingCandidate};
use ptmc::fpga::Device;
use ptmc::mem::MemTech;
use ptmc::shard::{partition_indices, shard_trace, ShardPlan, ShardedSweep};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::tensor::SparseTensor;
use ptmc::testkit::{forall, Rng};

/// A random synthetic tensor: 3 or 4 modes, varying nnz and skew.
fn random_tensor(rng: &mut Rng) -> SparseTensor {
    let n_modes = rng.range(3, 5);
    let dims: Vec<usize> = (0..n_modes).map(|_| rng.range(30, 300)).collect();
    let space: usize = dims.iter().product();
    let nnz = rng.range(1, 2_000).min(space / 4).max(1);
    let profile = match rng.below(3) {
        0 => Profile::Uniform,
        1 => Profile::Zipf {
            alpha_milli: 1_050 + rng.below(500) as u32,
        },
        _ => Profile::Clustered {
            block: 8,
            blocks: 20,
        },
    };
    generate(&SynthConfig {
        dims,
        nnz,
        profile,
        seed: rng.next_u64(),
    })
}

/// A subsampled joint grid: every candidate draws its cache geometry,
/// DRAM timing, DMA shape, and remapper budget independently, so the
/// batch is a genuinely joint cross-product sample (cache AND timing
/// knobs both vary).
fn random_joint_grid(rng: &mut Rng, base: &ControllerConfig) -> Vec<ControllerConfig> {
    const LINE_BYTES: [usize; 3] = [32, 64, 128];
    const GEOMS: [(usize, usize); 4] = [(64, 1), (256, 2), (1024, 4), (4096, 8)];
    const DRAMS: [(usize, usize, RowPolicy); 3] = [
        (1, 16, RowPolicy::Open),
        (2, 8, RowPolicy::Open),
        (4, 16, RowPolicy::Closed),
    ];
    const DMAS: [(usize, usize); 3] = [(1, 1024), (2, 4096), (4, 16384)];
    const POINTERS: [usize; 3] = [4, 1 << 10, 1 << 18];
    let n = rng.range(4, 10);
    (0..n)
        .map(|_| {
            let (num_lines, assoc) = GEOMS[rng.range(0, GEOMS.len())];
            let (channels, banks, policy) = DRAMS[rng.range(0, DRAMS.len())];
            let (num_dmas, buffer_bytes) = DMAS[rng.range(0, DMAS.len())];
            let mut cfg = base.clone();
            cfg.cache.line_bytes = LINE_BYTES[rng.range(0, LINE_BYTES.len())];
            cfg.cache.num_lines = num_lines;
            cfg.cache.assoc = assoc;
            {
                let dram = cfg.mem.ddr4_mut();
                dram.channels = channels;
                dram.banks = banks;
                dram.row_policy = policy;
            }
            cfg.dma.num_dmas = num_dmas;
            cfg.dma.buffer_bytes = buffer_bytes;
            cfg.remapper.max_pointers = POINTERS[rng.range(0, POINTERS.len())];
            cfg
        })
        .collect()
}

/// Fresh per-candidate event replay — the ground truth every joint
/// cell must reproduce bit-for-bit.
fn event_cycles(prepared: &PreparedTrace, cfg: &ControllerConfig) -> u64 {
    let mut ctl = MemoryController::new(cfg.clone());
    EngineKind::Event.replay(&mut ctl, prepared)
}

#[test]
fn joint_sweep_is_bit_identical_on_shard_traces() {
    forall("joint_sweep_shard_traces", 8, |rng| {
        let t = random_tensor(rng);
        let rank = [4usize, 8, 16][rng.range(0, 3)];
        let mode = rng.range(0, t.n_modes());
        let workers = rng.range(1, 4);
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let plan = ShardPlan::balance(&t, mode, workers);
        let parts = partition_indices(&t, &plan);
        let base = ControllerConfig::default_for(t.record_bytes());
        let cfgs = random_joint_grid(rng, &base);
        let pairs: Vec<_> = cfgs
            .iter()
            .map(|c| (c.cache, TimingCandidate::of(c)))
            .collect();
        let index = JointIndex::build(&pairs);
        let mut offset = 0usize;
        for (spec, zs) in plan.shards.iter().zip(&parts) {
            let trace = shard_trace(&t, rank, mode, &layout, spec, zs, offset);
            offset += spec.nnz;
            let prepared = PreparedTrace::new(trace);
            let got = index.sweep(prepared.compressed());
            assert_eq!(got.len(), cfgs.len());
            for (cfg, &cycles) in cfgs.iter().zip(&got) {
                assert_eq!(
                    cycles,
                    event_cycles(&prepared, cfg),
                    "joint sweep diverged from event replay for {:?}/{:?}/{:?}",
                    cfg.cache,
                    cfg.mem,
                    cfg.dma
                );
            }
            // The thread-chunked walk is the same computation.
            assert_eq!(got, index.sweep_parallel(prepared.compressed()));
        }
    });
}

#[test]
fn joint_sweep_is_bit_identical_on_adversarial_mixes() {
    // Cold classes, width changes, unaligned addresses, and far-apart
    // cached addresses exercise the compressor's fallback paths under
    // the classify → extract → multi-lane-walk composition.
    forall("joint_sweep_adversarial", 10, |rng| {
        let n = rng.range(1, 600);
        let mut trace = Vec::with_capacity(n);
        for i in 0..n as u64 {
            let a = match rng.below(8) {
                0 => Access::Stream {
                    addr: i * 4096,
                    bytes: 4096,
                },
                1 => Access::Stream {
                    addr: rng.below(1 << 30),
                    bytes: 1 + rng.below(8192) as usize,
                },
                2 => Access::Cached {
                    addr: (8 << 20) + rng.below(1 << 14) * 64,
                    bytes: 64,
                },
                3 => Access::Cached {
                    addr: rng.below(1 << 26),
                    bytes: 1 + rng.below(256) as usize,
                },
                4 => Access::Cached {
                    addr: (1 << 40) + rng.below(1 << 20) * 64,
                    bytes: 64,
                },
                5 => Access::Element {
                    addr: rng.below(1 << 32),
                    bytes: 16,
                },
                6 => Access::CachedStore {
                    addr: rng.below(1 << 24) * 16,
                    bytes: 16,
                },
                _ => Access::Stream {
                    addr: (2 << 30) + (i % 7) * 64,
                    bytes: 64,
                },
            };
            trace.push(a);
        }
        let prepared = PreparedTrace::new(trace);
        let base = ControllerConfig::default_for(16);
        let cfgs = random_joint_grid(rng, &base);
        let pairs: Vec<_> = cfgs
            .iter()
            .map(|c| (c.cache, TimingCandidate::of(c)))
            .collect();
        let index = JointIndex::build(&pairs);
        let got = index.sweep(prepared.compressed());
        for (cfg, &cycles) in cfgs.iter().zip(&got) {
            assert_eq!(
                cycles,
                event_cycles(&prepared, cfg),
                "adversarial joint sweep diverged for {:?}/{:?}",
                cfg.cache,
                cfg.mem
            );
        }
    });
}

#[test]
fn sharded_joint_grid_matches_per_candidate_makespans() {
    // The full sharded joint path: per-shard hierarchical traversal +
    // memoized remap must reproduce the event/lockstep makespan of
    // every joint candidate exactly — including candidates whose
    // channel counts split differently across workers and candidates
    // that only differ in the remapper budget.
    forall("sharded_joint_grid_vs_event", 4, |rng| {
        let t = random_tensor(rng);
        let workers = rng.range(1, 4);
        let sweep = ShardedSweep::prepare(&t, 8, workers);
        let base = ControllerConfig::default_for(t.record_bytes());
        let cands = random_joint_grid(rng, &base);
        let got = sweep.makespans_for_joint_grid(&cands);
        assert_eq!(got.len(), cands.len());
        for (cfg, &score) in cands.iter().zip(&got) {
            assert_eq!(
                score,
                sweep.makespan_with(cfg, EngineKind::Event),
                "sharded joint makespan diverged from event"
            );
            assert_eq!(
                score,
                sweep.makespan_with(cfg, EngineKind::Lockstep),
                "sharded joint makespan diverged from lockstep"
            );
        }
    });
}

#[test]
fn joint_explore_never_worse_than_coordinate_on_random_tensors() {
    // The acceptance property behind `--search joint`: on every test
    // grid the joint winner's score is <= coordinate descent's, and
    // the grid engine's hierarchical scoring agrees with per-candidate
    // event scoring point for point.
    forall("joint_explore_vs_coordinate", 3, |rng| {
        let t = random_tensor(rng);
        let rank = 8usize;
        let factors: Vec<Mat> = t
            .dims()
            .iter()
            .map(|&d| Mat::randn(d, rank, rng.next_u64()))
            .collect();
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let grids = Grids {
            cache_line_bytes: vec![32, 64],
            cache_num_lines: vec![256, 1024],
            cache_assoc: vec![2, 4],
            dma_num: vec![1, 2],
            dma_buffers: vec![2],
            dma_buffer_bytes: vec![4096],
            dram_channels: vec![1, 2],
            dram_banks: vec![16],
            dram_row_policy: vec![RowPolicy::Open],
            remap_max_pointers: vec![1 << 10, 1 << 18],
            mem_techs: vec![MemTech::Ddr4],
        };
        let joint = SearchOptions {
            strategy: SearchStrategy::Joint,
            top_k: 3,
            resume: false,
            checkpoint_every: 0,
        };
        let ev_grid = EvaluatorBuilder::new()
            .engine(EngineKind::Grid)
            .cycle_sim(&t, &factors);
        let ev_event = EvaluatorBuilder::new()
            .engine(EngineKind::Event)
            .cycle_sim(&t, &factors);
        let ex_coord = explore(&base, &grids, &dev, &ev_grid);
        let ex_joint = explore_with(&base, &grids, &dev, &ev_grid, &joint);
        assert!(
            ex_joint.best.cycles <= ex_coord.best.cycles,
            "joint {} must be <= coordinate {}",
            ex_joint.best.cycles,
            ex_coord.best.cycles
        );
        let ex_joint_event = explore_with(&base, &grids, &dev, &ev_event, &joint);
        assert_eq!(ex_joint.visited.len(), ex_joint_event.visited.len());
        for (a, b) in ex_joint.visited.iter().zip(&ex_joint_event.visited) {
            assert_eq!(a.cycles, b.cycles, "joint scores diverged between engines");
        }
        assert_eq!(ex_joint.best.cfg, ex_joint_event.best.cfg);
    });
}
