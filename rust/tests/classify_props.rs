//! Property tests for the branch-light SoA classification kernel
//! (S28, `ptmc::engine::grid::ClassifyKernel::Soa`): on random,
//! adversarial, shard-derived, and windowed traces, the SoA kernel
//! must be **bit-identical** to the scalar oracle across the full
//! `Grids::default()` cache candidate set — identical per-candidate
//! hit/miss/eviction/writeback statistics *and* identical miss-only
//! replays (cycles plus every controller counter).

use ptmc::controller::{Access, CacheConfig, ControllerConfig, MemLayout};
use ptmc::dse::Grids;
use ptmc::engine::{
    ChunkedWindows, ClassifyKernel, CoalescedWindows, CompressedTrace, GridClassification,
};
use ptmc::shard::{partition_indices, shard_trace, ShardPlan};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::testkit::{forall, Rng};

/// Every valid cache candidate of the default DSE grid (the same
/// power-of-two-sets filter `dse::explore` applies).
fn default_grid_configs() -> Vec<CacheConfig> {
    let g = Grids::default();
    let mut configs = Vec::new();
    for &line_bytes in &g.cache_line_bytes {
        for &num_lines in &g.cache_num_lines {
            for &assoc in &g.cache_assoc {
                if num_lines % assoc != 0 || !(num_lines / assoc).is_power_of_two() {
                    continue;
                }
                configs.push(CacheConfig {
                    line_bytes,
                    num_lines,
                    assoc,
                    hit_latency: 2,
                });
            }
        }
    }
    configs
}

/// Random cache-class trace: hot zipf rows, cold unaligned addresses,
/// line-straddling widths, and stores mixed in.
fn random_cache_trace(rng: &mut Rng) -> Vec<Access> {
    let n = rng.range(50, 1_200);
    let mut trace = Vec::with_capacity(n);
    for _ in 0..n {
        let addr = match rng.below(4) {
            0 => rng.zipf(4096, 1.2) * 64,
            1 => rng.below(1 << 22),
            2 => (8 << 20) + rng.below(1 << 10) * 256,
            _ => rng.below(1 << 16) * 64,
        };
        let bytes = match rng.below(4) {
            0 => 16,
            1 => 64,
            2 => 1 + rng.below(300) as usize,
            _ => 4,
        };
        if rng.below(4) == 0 {
            trace.push(Access::CachedStore { addr, bytes });
        } else {
            trace.push(Access::Cached { addr, bytes });
        }
    }
    trace
}

/// Assert the two kernels classify `trace` identically for every
/// candidate: statistics and full miss-only replays.
fn assert_kernels_identical(trace: &[Access], configs: &[CacheConfig], what: &str) {
    let ct = CompressedTrace::compress(trace);
    let scalar = GridClassification::classify_with(&ct, configs, ClassifyKernel::Scalar);
    let soa = GridClassification::classify_with(&ct, configs, ClassifyKernel::Soa);
    for (i, cc) in configs.iter().enumerate() {
        assert_eq!(
            scalar.cache_stats(i),
            soa.cache_stats(i),
            "{what}: stats diverged for {cc:?}"
        );
        let mut cfg = ControllerConfig::default_for(16);
        cfg.cache = *cc;
        assert_eq!(
            scalar.replay(i, &ct, &cfg),
            soa.replay(i, &ct, &cfg),
            "{what}: replay diverged for {cc:?}"
        );
    }
}

#[test]
fn soa_kernel_matches_scalar_oracle_on_the_default_grid() {
    let configs = default_grid_configs();
    assert!(configs.len() >= 32, "default grid should be non-trivial");
    forall("soa_vs_scalar_default_grid", 8, |rng| {
        let trace = random_cache_trace(rng);
        assert_kernels_identical(&trace, &configs, "random trace");
    });
}

#[test]
fn soa_kernel_matches_scalar_oracle_on_adversarial_mixes() {
    // Degenerate shapes the branchless lanes must still get right:
    // single-set caches, repeated hits to one line, eviction storms
    // cycling through exactly assoc+1 lines, dirty-line ping-pong, and
    // addresses beyond the u32 delta window.
    let configs = default_grid_configs();
    forall("soa_vs_scalar_adversarial", 8, |rng| {
        let n = rng.range(1, 500);
        let mut trace = Vec::with_capacity(n);
        for i in 0..n as u64 {
            let a = match rng.below(6) {
                0 => Access::Cached { addr: 0, bytes: 16 },
                1 => Access::Cached {
                    // Cycle assoc+1 lines of one set for 16384-line caches.
                    addr: (i % 9) * (16384 / 8) * 256,
                    bytes: 64,
                },
                2 => Access::CachedStore {
                    addr: (i % 2) * (1 << 22),
                    bytes: 16,
                },
                3 => Access::Cached {
                    addr: (1 << 40) + rng.below(1 << 18) * 64,
                    bytes: 64,
                },
                4 => Access::Cached {
                    addr: rng.below(1 << 26),
                    bytes: 1 + rng.below(700) as usize,
                },
                _ => Access::CachedStore {
                    addr: rng.zipf(64, 1.4) * 32,
                    bytes: 32,
                },
            };
            trace.push(a);
        }
        assert_kernels_identical(&trace, &configs, "adversarial trace");
    });
}

#[test]
fn soa_kernel_matches_scalar_oracle_on_shard_traces() {
    let configs = default_grid_configs();
    forall("soa_vs_scalar_shard_traces", 4, |rng| {
        let dims: Vec<usize> = (0..3).map(|_| rng.range(40, 200)).collect();
        let space: usize = dims.iter().product();
        let nnz = rng.range(100, 1_500).min(space / 4).max(1);
        let t = generate(&SynthConfig {
            dims,
            nnz,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: rng.next_u64(),
        });
        let rank = 8;
        let mode = rng.range(0, t.n_modes());
        let workers = rng.range(1, 4);
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let plan = ShardPlan::balance(&t, mode, workers);
        let parts = partition_indices(&t, &plan);
        let mut offset = 0usize;
        for (spec, zs) in plan.shards.iter().zip(&parts) {
            let trace = shard_trace(&t, rank, mode, &layout, spec, zs, offset);
            offset += spec.nnz;
            assert_kernels_identical(&trace, &configs, "shard trace");
        }
    });
}

#[test]
fn soa_kernel_is_window_boundary_invariant() {
    // Windowed classification threads the SoA stacks, the per-slot
    // last-miss line counters, and the pass-global line number across
    // windows; both kernels must agree at every window size, including
    // after re-blocking through `CoalescedWindows`.
    let configs = default_grid_configs();
    forall("soa_vs_scalar_windowed", 6, |rng| {
        let trace = random_cache_trace(rng);
        let ct = CompressedTrace::compress(&trace);
        let mono = GridClassification::classify_with(&ct, &configs, ClassifyKernel::Scalar);
        for window in [1usize, 7, 64, 513, 100_000] {
            let mut src = ChunkedWindows::new(&trace, window);
            let win =
                GridClassification::classify_source_with(&mut src, &configs, ClassifyKernel::Soa);
            for (i, cc) in configs.iter().enumerate() {
                assert_eq!(
                    mono.cache_stats(i),
                    win.cache_stats(i),
                    "window {window}: {cc:?}"
                );
            }
        }
        let mut inner = ChunkedWindows::new(&trace, 3);
        let mut coalesced = CoalescedWindows::new(&mut inner, 256);
        let co = GridClassification::classify_source_with(
            &mut coalesced,
            &configs,
            ClassifyKernel::Soa,
        );
        for (i, cc) in configs.iter().enumerate() {
            assert_eq!(mono.cache_stats(i), co.cache_stats(i), "coalesced: {cc:?}");
        }
    });
}
