//! Crash-consistency property suite for the fault-injection layer
//! (S31, `ptmc::util::fault`): under randomized deterministic fault
//! schedules the pipeline must either fail with a clean *typed* error
//! or produce results bit-identical to the fault-free oracle; a warm
//! explore killed at any checkpoint (emulated by failing every flush
//! past the Nth) must resume via `--warm-cache` byte-for-byte; shard
//! worker panics surface as [`ErrorClass::Worker`] instead of a
//! poisoned join; and transient IO faults are retried away without
//! changing a single bit of output.
//!
//! Tests that *must not* observe injected faults (oracles, resume
//! runs) still arm a never-firing plan so they hold the process-wide
//! fault lock and cannot race an armed test on another thread.

use std::path::PathBuf;
use std::sync::Arc;

use ptmc::bench::{json_section, upsert_json_file};
use ptmc::controller::ControllerConfig;
use ptmc::cpd::linalg::Mat;
use ptmc::dram::RowPolicy;
use ptmc::dse::{
    explore_with, tensor_fingerprint, EvaluatorBuilder, Exploration, Grids, KeyBuilder, Point,
    SearchOptions, SearchStrategy, WarmCache,
};
use ptmc::engine::EngineKind;
use ptmc::error::ErrorClass;
use ptmc::fpga::Device;
use ptmc::mem::MemTech;
use ptmc::pms::TensorProfile;
use ptmc::shard::try_mttkrp_sharded_with_engine;
use ptmc::tensor::frostt::{TnsBlockReader, TnsError};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::tensor::SparseTensor;
use ptmc::testkit::forall;
use ptmc::util::fault;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ptmc_fault_props_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn tensor(seed: u64) -> SparseTensor {
    generate(&SynthConfig {
        dims: vec![120, 90, 60],
        nnz: 3_000,
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed,
    })
}

fn small_grids() -> Grids {
    Grids {
        cache_line_bytes: vec![32, 64],
        cache_num_lines: vec![256, 1024],
        cache_assoc: vec![2, 4],
        dma_num: vec![1, 2],
        dma_buffers: vec![2],
        dma_buffer_bytes: vec![4096],
        mem_techs: vec![MemTech::Ddr4],
        dram_channels: vec![1, 2],
        dram_banks: vec![16],
        dram_row_policy: vec![RowPolicy::Open],
        remap_max_pointers: vec![1 << 10, 1 << 18],
    }
}

fn pms_key(t: &SparseTensor, dev: &Device) -> u64 {
    KeyBuilder::new(tensor_fingerprint(t))
        .evaluator("pms")
        .rank(16)
        .device(dev)
        .finish()
}

fn assert_points_identical(a: &[Point], b: &[Point], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: lengths diverged");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.cfg, y.cfg, "{what}: configs diverged");
        assert_eq!(
            x.cycles.to_bits(),
            y.cycles.to_bits(),
            "{what}: cycles diverged"
        );
        assert_eq!(x.bram36, y.bram36, "{what}: bram36 diverged");
        assert_eq!(x.uram, y.uram, "{what}: uram diverged");
    }
}

fn assert_explorations_identical(a: &Exploration, b: &Exploration) {
    assert_points_identical(
        std::slice::from_ref(&a.best),
        std::slice::from_ref(&b.best),
        "best",
    );
    assert_points_identical(&a.visited, &b.visited, "visited");
    assert_eq!(a.rejected, b.rejected, "rejected counts diverged");
    assert_points_identical(&a.pareto, &b.pareto, "pareto");
    assert_points_identical(&a.top, &b.top, "top-k");
}

/// Hold the fault lock with a plan that cannot fire on any path these
/// tests exercise (`bench.upsert` hit one million) — serializes a
/// fault-free section against armed tests on other threads.
fn quiesce() -> fault::FaultGuard {
    fault::arm("bench.upsert@1000000").expect("never-firing plan must parse")
}

fn assert_mats_identical(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape");
    for (x, y) in a.data().iter().zip(b.data()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: values diverged");
    }
}

#[test]
fn randomized_warm_fault_schedules_never_change_results() {
    // Any schedule of warm-cache load/flush faults — transient or
    // persistent, one-shot or repeating — degrades the cache to cold
    // at worst; the exploration itself must stay bit-identical to the
    // fault-free oracle.
    let t = tensor(31);
    let profile = TensorProfile::measure(&t);
    let base = ControllerConfig::default_for(t.record_bytes());
    let dev = Device::alveo_u250();
    let grids = small_grids();
    let opts = SearchOptions::default();
    let key = pms_key(&t, &dev);
    let oracle = {
        let _q = quiesce();
        let eval = EvaluatorBuilder::new().rank(16).pms(&profile);
        explore_with(&base, &grids, &dev, &eval, &opts)
    };

    const KINDS: [&str; 6] = [
        "notfound",
        "permissiondenied",
        "interrupted",
        "timedout",
        "unexpectedeof",
        "other",
    ];
    forall("warm_fault_schedules", 6, |rng| {
        let plan = format!(
            "warm.flush@{}{}:{};warm.load@{}:{}",
            rng.range(1, 4),
            if rng.below(2) == 0 { "%1" } else { "" },
            KINDS[rng.range(0, KINDS.len())],
            rng.range(1, 3),
            KINDS[rng.range(0, KINDS.len())],
        );
        let dir = tmp_dir(&format!("sched_{:08x}", rng.next_u64() as u32));
        let guard = fault::arm(&plan).unwrap();
        let cache = Arc::new(WarmCache::open(&dir, key));
        let warm = Some(Arc::clone(&cache));
        let eval = EvaluatorBuilder::new().rank(16).warm_cache(warm).pms(&profile);
        let ex = explore_with(&base, &grids, &dev, &eval, &opts);
        assert_explorations_identical(&oracle, &ex);
        drop(guard);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn kill_at_any_checkpoint_resumes_byte_identically() {
    // SIGKILL emulation: with `--checkpoint-every 1` a coordinate
    // explore flushes after every module sweep.  Failing every flush
    // from the Kth on leaves the on-disk cache frozen at checkpoint
    // K-1 — exactly the state a kill between flushes K-1 and K leaves
    // behind.  A fresh warm explore over that prefix must reproduce
    // the uninterrupted run byte-for-byte AND heal the cache file to
    // the same bytes an uninterrupted warm run writes.
    let t = tensor(37);
    let profile = TensorProfile::measure(&t);
    let base = ControllerConfig::default_for(t.record_bytes());
    let dev = Device::alveo_u250();
    let grids = small_grids();
    let opts = SearchOptions {
        strategy: SearchStrategy::Coordinate,
        top_k: 3,
        resume: false,
        checkpoint_every: 1,
    };
    let key = pms_key(&t, &dev);

    // Fault-free oracles: the exploration, the cache bytes an
    // uninterrupted warm run persists, and — via a never-firing rule
    // on the flush site — how many flushes the run performs, so the
    // kill loop below covers every possible kill point.
    let (oracle, oracle_bytes, flushes) = {
        let probe = fault::arm("warm.flush@1000000").unwrap();
        let dir = tmp_dir("ckpt_oracle");
        let cache = Arc::new(WarmCache::open(&dir, key));
        let warm = Some(Arc::clone(&cache));
        let eval = EvaluatorBuilder::new().rank(16).warm_cache(warm).pms(&profile);
        let ex = explore_with(&base, &grids, &dev, &eval, &opts);
        let bytes = std::fs::read(cache.path()).expect("oracle cache file must exist");
        let flushes = fault::hit_count(fault::WARM_FLUSH) as usize;
        drop(probe);
        let _ = std::fs::remove_dir_all(&dir);
        (ex, bytes, flushes)
    };
    assert!(
        flushes >= 2,
        "checkpoint-every 1 must flush mid-search, not just at the end (saw {flushes})"
    );

    for kill_at in 1..=flushes {
        let dir = tmp_dir(&format!("ckpt_kill{kill_at}"));

        // Phase 1: the "killed" run — flushes 1..kill_at-1 land, every
        // later flush (checkpoints and the final one) fails.
        {
            let guard = fault::arm(&format!("warm.flush@{kill_at}%1:other")).unwrap();
            let cache = Arc::new(WarmCache::open(&dir, key));
            let warm = Some(Arc::clone(&cache));
            let eval = EvaluatorBuilder::new().rank(16).warm_cache(warm).pms(&profile);
            let ex = explore_with(&base, &grids, &dev, &eval, &opts);
            // Even the "killed" process computed correct results up to
            // the kill; only its persistence was cut short.
            assert_explorations_identical(&oracle, &ex);
            assert!(cache.is_degraded(), "kill_at={kill_at}: flush faults must degrade");
            assert!(fault::injected_count() > 0, "kill_at={kill_at}: plan never fired");
            drop(guard);
        }

        // Phase 2: resume from whatever checkpoint survived.
        {
            let _q = quiesce();
            let cache = Arc::new(WarmCache::open(&dir, key));
            if kill_at == 1 {
                assert!(
                    cache.is_empty(),
                    "first flush already failed: resume must start cold"
                );
            }
            let warm = Some(Arc::clone(&cache));
            let eval = EvaluatorBuilder::new().rank(16).warm_cache(warm).pms(&profile);
            let resumed = explore_with(&base, &grids, &dev, &eval, &opts);
            assert_explorations_identical(&oracle, &resumed);
            let healed = std::fs::read(cache.path()).expect("resume must heal the cache");
            assert_eq!(
                healed, oracle_bytes,
                "kill_at={kill_at}: healed cache bytes diverged from the uninterrupted run"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn no_checkpoint_file_is_ever_torn() {
    // Every checkpoint goes through the atomic temp+rename writer, so
    // after any prefix of successful flushes the on-disk file is a
    // complete, parseable cache — opening it never falls back to cold
    // once at least one flush landed.
    let t = tensor(41);
    let profile = TensorProfile::measure(&t);
    let base = ControllerConfig::default_for(t.record_bytes());
    let dev = Device::alveo_u250();
    let grids = small_grids();
    let opts = SearchOptions {
        strategy: SearchStrategy::Coordinate,
        top_k: 1,
        resume: false,
        checkpoint_every: 1,
    };
    let key = pms_key(&t, &dev);
    let dir = tmp_dir("torn");
    {
        let guard = fault::arm("warm.flush@2%1:other").unwrap();
        let cache = Arc::new(WarmCache::open(&dir, key));
        let warm = Some(Arc::clone(&cache));
        let eval = EvaluatorBuilder::new().rank(16).warm_cache(warm).pms(&profile);
        explore_with(&base, &grids, &dev, &eval, &opts);
        drop(guard);
    }
    {
        let _q = quiesce();
        let cache = WarmCache::open(&dir, key);
        assert!(
            !cache.is_empty(),
            "checkpoint 1 landed before the faults: it must parse"
        );
        assert!(!cache.is_degraded(), "a clean open must not degrade");
        // The failed flushes left no temp-file litter behind.
        let litter: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(litter.is_empty(), "tmp litter: {litter:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shard_worker_panics_surface_as_typed_worker_errors() {
    let t = tensor(43);
    let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 9)).collect();
    let guard = fault::arm("shard.worker@1:panic").unwrap();
    let err = try_mttkrp_sharded_with_engine(&t, &factors, 0, 2, None, EngineKind::Lockstep)
        .expect_err("an injected worker panic must not produce a result");
    assert_eq!(err.class(), ErrorClass::Worker);
    assert_eq!(err.class().exit_code(), 6);
    let msg = err.to_string();
    assert!(msg.contains("shard worker"), "{msg}");
    assert!(msg.contains("injected panic"), "{msg}");

    // The plan is exhausted (one-shot rule): the same call now
    // succeeds under the same guard — the executor survived the panic
    // without poisoning anything.
    let ok = try_mttkrp_sharded_with_engine(&t, &factors, 0, 2, None, EngineKind::Lockstep)
        .expect("post-panic run must succeed");
    assert_eq!(ok.output.rows(), t.dims()[0]);
    drop(guard);
}

#[test]
fn shard_worker_transient_faults_retry_to_identical_results() {
    let t = tensor(47);
    let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 11)).collect();
    let guard = fault::arm("shard.worker@1:interrupted").unwrap();
    let faulted = try_mttkrp_sharded_with_engine(&t, &factors, 0, 2, None, EngineKind::Lockstep)
        .expect("a one-shot transient fault must be retried away");
    assert_eq!(fault::injected_count(), 1, "the transient fault must have fired");
    // Plan exhausted: this run is the fault-free oracle.
    let oracle = try_mttkrp_sharded_with_engine(&t, &factors, 0, 2, None, EngineKind::Lockstep)
        .expect("oracle run must succeed");
    assert_mats_identical(&faulted.output, &oracle.output, "retried output");
    drop(guard);

    // A persistent (repeating) non-transient fault is a typed error.
    let guard = fault::arm("shard.worker@1%1:brokenpipe").unwrap();
    let err = try_mttkrp_sharded_with_engine(&t, &factors, 0, 2, None, EngineKind::Lockstep)
        .expect_err("a persistent fault must fail the mode");
    assert_eq!(err.class(), ErrorClass::Worker);
    assert!(err.to_string().contains("BrokenPipe") || err.to_string().contains("injected"));
    drop(guard);
}

#[test]
fn frostt_read_faults_are_typed_io_errors() {
    let text = "1 1 1 1.0\n2 2 2 2.0\n3 3 3 3.0\n";
    let guard = fault::arm("frostt.read_block@1:unexpectedeof").unwrap();
    let mut r = TnsBlockReader::new(std::io::Cursor::new(text.as_bytes()), 2);
    match r.next_block() {
        Err(TnsError::Io(e)) => assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof),
        other => panic!("expected a typed IO error, got {other:?}"),
    }
    drop(guard);

    // Fault-free, the same stream parses completely.
    let _q = quiesce();
    let mut r = TnsBlockReader::new(std::io::Cursor::new(text.as_bytes()), 2);
    let mut nnz = 0usize;
    while let Some(b) = r.next_block().expect("clean stream must parse") {
        nnz += b.nnz();
    }
    assert_eq!(nnz, 3);
}

#[test]
fn bench_upserts_fail_clean_and_retry_transients() {
    let dir = tmp_dir("upsert");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_test.json");
    {
        let _g = fault::arm("bench.upsert@1%1:notfound").unwrap();
        let e = upsert_json_file(&path, "a", "1").unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::NotFound);
        assert!(!path.exists(), "a failed upsert must not create the file");
        assert!(
            !path.with_extension("tmp").exists(),
            "a failed upsert must not leave tmp litter"
        );
    }
    {
        let _g = fault::arm("bench.upsert@1:interrupted").unwrap();
        upsert_json_file(&path, "a", "1").expect("transient upsert fault must be retried");
        upsert_json_file(&path, "b", "{ \"x\": 2 }").unwrap();
    }
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(json_section(&text, "a").as_deref(), Some("1"));
    assert!(json_section(&text, "b").is_some(), "sections must accumulate");
    assert!(!path.with_extension("tmp").exists());
    let _ = std::fs::remove_dir_all(&dir);
}
