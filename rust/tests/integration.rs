//! Cross-module integration tests: full pipelines spanning tensor IO,
//! remap, the MTTKRP engines, the memory-controller simulator, CP-ALS,
//! the PMS/DSE pair, and (when artifacts are present) the PJRT runtime.

use ptmc::controller::{Access, ControllerConfig, MemLayout, MemoryController};
use ptmc::cpd::linalg::Mat;
use ptmc::cpd::{cp_als, AlsConfig, MttkrpBackend, NativeBackend, SimBackend};
use ptmc::dse::{explore, Evaluator, EvaluatorBuilder, Grids};
use ptmc::engine::EngineKind;
use ptmc::fpga::Device;
use ptmc::mttkrp::{approach1, oracle, remap_exec, Tracing};
use ptmc::pms::{self, TensorProfile};
use ptmc::shard::{self, ParallelBackend};
use ptmc::tensor::synth::{generate, low_rank, Profile, SynthConfig};
use ptmc::tensor::{frostt, remap, SparseTensor};
use ptmc::testkit::assert_allclose;

fn tensor(seed: u64, nnz: usize) -> SparseTensor {
    generate(&SynthConfig {
        dims: vec![500, 400, 300],
        nnz,
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed,
    })
}

#[test]
fn tns_file_to_decomposition() {
    // Write a low-rank tensor to .tns, read it back, decompose, recover.
    let t = low_rank(&[20, 16, 12], 3, 0.02, 5);
    let mut buf = Vec::new();
    frostt::write_tns(&t, &mut buf).unwrap();
    let mut t2 = frostt::read_tns(&buf[..]).unwrap();
    assert_eq!(t2.nnz(), t.nnz());

    let cfg = AlsConfig {
        rank: 3,
        max_iters: 25,
        tol: 1e-7,
        ..Default::default()
    };
    let model = cp_als(&mut t2, &cfg, &mut NativeBackend);
    assert!(model.final_fit() > 0.9, "fit {}", model.final_fit());
}

#[test]
fn remap_then_approach1_equals_oracle_through_controller() {
    let mut t = tensor(1, 5_000);
    let factors: Vec<Mat> = t
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| Mat::randn(d, 16, m as u64))
        .collect();
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 16);
    let mut ctl = MemoryController::new(ControllerConfig::default_for(t.record_bytes()));

    for mode in 0..3 {
        let want = oracle::mttkrp(&t, &factors, mode);
        let run = remap_exec::run(&mut t, &factors, mode, &layout, &mut ctl, 0);
        assert_allclose(run.engine.output.data(), want.data(), 1e-4, 1e-4);
    }
    assert!(ctl.now() > 0);
    assert!(ctl.cache_stats().hit_rate() > 0.3, "zipf rows should hit");
}

#[test]
fn full_als_sim_vs_native_same_fit_and_nonzero_cycles() {
    let mut ta = tensor(2, 4_000);
    let mut tb = ta.clone();
    let cfg = AlsConfig {
        rank: 8,
        max_iters: 4,
        tol: 0.0,
        ..Default::default()
    };
    let native = cp_als(&mut ta, &cfg, &mut NativeBackend);
    let layout = MemLayout::plan(tb.dims(), tb.nnz(), tb.record_bytes(), cfg.rank);
    let mut sim = SimBackend::new(
        MemoryController::new(ControllerConfig::default_for(tb.record_bytes())),
        layout,
    );
    let simmed = cp_als(&mut tb, &cfg, &mut sim);
    assert!((native.final_fit() - simmed.final_fit()).abs() < 1e-3);
    assert!(simmed.cycles > 0);
}

#[test]
fn dse_winner_beats_loser_when_resimulated() {
    let t = tensor(3, 10_000);
    let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 16, 9)).collect();
    let profile = TensorProfile::measure(&t);
    let dev = Device::alveo_u250();
    let base = ControllerConfig::default_for(t.record_bytes());
    let ex = explore(
        &base,
        &Grids::default(),
        &dev,
        &Evaluator::Pms {
            profile: &profile,
            rank: 16,
        },
    );
    // Re-simulate best + a deliberately bad config with the cycle model.
    let sim = EvaluatorBuilder::new()
        .engine(EngineKind::Event)
        .cycle_sim(&t, &factors);
    let best_cycles = sim.score(&ex.best.cfg, &dev).unwrap();
    let mut bad = base.clone();
    bad.cache.num_lines = 64;
    bad.cache.assoc = 1;
    bad.dma.buffer_bytes = 64;
    bad.dma.buffers_per_dma = 1;
    bad.remapper.max_pointers = 8;
    let bad_cycles = sim.score(&bad, &dev).unwrap();
    assert!(
        best_cycles < bad_cycles,
        "PMS-chosen config ({best_cycles}) must beat a crippled one ({bad_cycles})"
    );
}

#[test]
fn pms_tracks_simulator_on_fresh_tensor() {
    let t = tensor(4, 20_000);
    let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 16, 11)).collect();
    let profile = TensorProfile::measure(&t);
    let dev = Device::alveo_u250();
    let cfg = ControllerConfig::default_for(t.record_bytes());
    let est = pms::estimate_with_rank(&profile, &cfg, &dev, 16).total_cycles();
    let sim = EvaluatorBuilder::new()
        .engine(EngineKind::Lockstep)
        .cycle_sim(&t, &factors)
        .score(&cfg, &dev)
        .unwrap();
    let rel = (est - sim).abs() / sim;
    assert!(rel < 0.30, "PMS {est:.3e} vs sim {sim:.3e} ({rel:.2})");
}

#[test]
fn controller_trace_cycles_are_deterministic() {
    let mut t = tensor(5, 3_000);
    t.sort_by_mode(0);
    let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 2)).collect();
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
    let run = approach1::run(&t, &factors, 0, &layout, Tracing::On);
    let cycles: Vec<u64> = (0..3)
        .map(|_| {
            let mut ctl =
                MemoryController::new(ControllerConfig::default_for(t.record_bytes()));
            ctl.replay(&run.trace)
        })
        .collect();
    assert_eq!(cycles[0], cycles[1]);
    assert_eq!(cycles[1], cycles[2]);
}

#[test]
fn remap_report_feeds_controller_consistently() {
    // The host-side remap accounting and the remapper-module simulation
    // must agree on element counts and spill behaviour.
    let mut t = tensor(6, 8_000);
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 16);
    let mut cfg = ControllerConfig::default_for(t.record_bytes());
    cfg.remapper.max_pointers = 32;
    let mut ctl = MemoryController::new(cfg.clone());
    ctl.remap_pass(t.mode_col(1), t.dims()[1], &layout, 0, 1);
    let report = remap::remap(&mut t, 1, cfg.remapper.max_pointers);
    let stats = ctl.remapper_stats();
    assert_eq!(stats.elements as usize, report.elements);
    assert_eq!(
        stats.spilled_cursor_elems * 2,
        report.spilled_pointer_accesses as u64
    );
}

#[test]
fn mixed_access_stream_is_fifo_ordered() {
    let mut ctl = MemoryController::new(ControllerConfig::default_for(16));
    let mut last = 0;
    for i in 0..200u64 {
        let t = match i % 3 {
            0 => ctl.request(Access::Stream {
                addr: i * 4096,
                bytes: 2048,
            }),
            1 => ctl.request(Access::Cached {
                addr: (i % 7) * 64,
                bytes: 64,
            }),
            _ => ctl.request(Access::Element {
                addr: (1 << 30) + i * 16384,
                bytes: 16,
            }),
        };
        assert!(t >= last, "FIFO completion must be monotone");
        last = t;
    }
}

#[test]
fn parallel_backend_cp_als_matches_native_for_k_1_2_4() {
    let cfg = AlsConfig {
        rank: 6,
        max_iters: 3,
        tol: 0.0,
        ..Default::default()
    };
    let mut tn = tensor(8, 5_000);
    let native = cp_als(&mut tn, &cfg, &mut NativeBackend);
    for k in [1usize, 2, 4] {
        let mut tp = tensor(8, 5_000);
        let mut b = ParallelBackend::new(k);
        let par = cp_als(&mut tp, &cfg, &mut b);
        assert!(
            (par.final_fit() - native.final_fit()).abs() < 1e-6,
            "k={k}: fit {} vs native {}",
            par.final_fit(),
            native.final_fit()
        );
        for (m, (fp, fa)) in par.factors.iter().zip(&native.factors).enumerate() {
            assert_allclose(fp.data(), fa.data(), 0.0, 1e-6);
            assert_eq!(fp.rows(), tn.dims()[m]);
        }
    }
}

#[test]
fn parallel_backend_with_controllers_full_stack() {
    // cp_als on the sharded backend with per-worker controller
    // simulation: the clock advances, the aggregate statistics are
    // populated, and sharded MTTKRP agrees with the oracle directly.
    let mut t = tensor(9, 6_000);
    let factors: Vec<Mat> = t
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| Mat::randn(d, 8, m as u64 + 90))
        .collect();
    for mode in 0..3 {
        let want = oracle::mttkrp(&t, &factors, mode);
        let run = shard::mttkrp_sharded(&t, &factors, mode, 4, None);
        assert_allclose(run.output.data(), want.data(), 0.0, 1e-6);
    }

    let cfg = AlsConfig {
        rank: 8,
        max_iters: 2,
        tol: 0.0,
        ..Default::default()
    };
    let ctl_cfg = ControllerConfig::default_for(t.record_bytes());
    let mut b = ParallelBackend::with_controller(4, ctl_cfg);
    let model = cp_als(&mut t, &cfg, &mut b);
    assert!(model.cycles > 0);
    // 4 worker controllers + 1 remap controller, per mode per iteration.
    assert_eq!(b.stats().controllers, 2 * 3 * 5);
    assert!(b.stats().cache.hit_rate() > 0.0);
    assert_eq!(b.metrics().nnz, 2 * 3 * 6_000);
}

#[test]
fn pjrt_full_stack_when_artifacts_present() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return;
    }
    use ptmc::coordinator::PjrtCoordinator;
    let mut t = tensor(7, 6_000);
    let mut c = PjrtCoordinator::open_default().unwrap();
    let factors: Vec<Mat> = t
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| Mat::randn(d, 16, m as u64 + 70))
        .collect();
    for mode in 0..3 {
        let want = oracle::mttkrp(&t, &factors, mode);
        let got = c.mttkrp(&mut t, &factors, mode);
        assert_allclose(got.data(), want.data(), 1e-4, 1e-4);
    }
    assert!(c.metrics().nnz >= 18_000);
}
