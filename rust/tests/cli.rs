//! End-to-end CLI tests: spawn the real `ptmc` binary
//! (`CARGO_BIN_EXE_ptmc`) and check each subcommand's contract.

use std::process::Command;

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_ptmc"))
        .args(args)
        .output()
        .expect("spawn ptmc");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

/// Like `run`, but also surfaces the raw exit code so tests can pin
/// the per-error-class contract (see `ptmc::error::ErrorClass`).
fn run_code(args: &[&str]) -> (Option<i32>, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_ptmc"))
        .args(args)
        .output()
        .expect("spawn ptmc");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.code(), text)
}

const SMALL: &[&str] = &["--synth", "zipf", "--dims", "200x150x100", "--nnz", "5000"];

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["--help"]);
    assert!(ok);
    for sub in ["decompose", "simulate", "shard", "pms", "explore", "stats"] {
        assert!(text.contains(sub), "help missing {sub}: {text}");
    }
    assert!(text.contains("--workers"), "help missing --workers: {text}");
}

#[test]
fn stats_reports_table2_fields() {
    let (ok, text) = run(&[&["stats"], SMALL].concat());
    assert!(ok, "{text}");
    assert!(text.contains("non-zeros:         5000"));
    assert!(text.contains("modes:             3"));
    assert!(text.contains("skew"));
}

#[test]
fn simulate_reports_cycles_and_overhead() {
    let (ok, text) = run(&[&["simulate"], SMALL, &["--rank", "16"]].concat());
    assert!(ok, "{text}");
    assert!(text.contains("total cycles:"));
    assert!(text.contains("overhead"));
    assert!(text.contains("cache:"));
}

#[test]
fn decompose_native_prints_fit_curve() {
    let (ok, text) = run(&[
        &["decompose"],
        SMALL,
        &["--rank", "4", "--iters", "3", "--backend", "native", "--tol", "0"],
    ]
    .concat());
    assert!(ok, "{text}");
    assert_eq!(text.matches("fit ").count(), 3, "{text}");
    assert!(text.contains("final fit:"));
}

#[test]
fn decompose_sim_reports_cycles() {
    let (ok, text) = run(&[
        &["decompose"],
        SMALL,
        &["--rank", "4", "--iters", "2", "--backend", "sim", "--tol", "0"],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("simulated memory cycles:"), "{text}");
}

#[test]
fn shard_reports_plan_for_one_mode() {
    let (ok, text) = run(&[&["shard"], SMALL, &["--workers", "4", "--mode", "0"]].concat());
    assert!(ok, "{text}");
    assert!(text.contains("4 workers"), "{text}");
    assert!(text.contains("imbalance"), "{text}");
    assert_eq!(text.matches("coords [").count(), 4, "{text}");
}

#[test]
fn shard_defaults_to_all_modes() {
    let (ok, text) = run(&[&["shard"], SMALL, &["--workers", "2"]].concat());
    assert!(ok, "{text}");
    for mode in 0..3 {
        assert!(text.contains(&format!("mode {mode}:")), "{text}");
    }
    assert_eq!(text.matches("coords [").count(), 6, "{text}");
}

#[test]
fn shard_rejects_out_of_range_mode() {
    let (ok, text) = run(&[&["shard"], SMALL, &["--mode", "7"]].concat());
    assert!(!ok);
    assert!(text.contains("out of range"), "{text}");
}

#[test]
fn decompose_parallel_reports_workers_and_cycles() {
    let (ok, text) = run(&[
        &["decompose"],
        SMALL,
        &[
            "--rank", "4", "--iters", "2", "--backend", "parallel", "--workers", "4",
            "--tol", "0",
        ],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("parallel: 4 workers"), "{text}");
    assert!(text.contains("simulated memory cycles:"), "{text}");
    assert!(text.contains("final fit:"), "{text}");
}

#[test]
fn pms_reports_estimate_and_resources() {
    let (ok, text) = run(&[&["pms"], SMALL, &["--device", "u280"]].concat());
    assert!(ok, "{text}");
    assert!(text.contains("total estimate:"));
    assert!(text.contains("BRAM36"));
    assert!(text.contains("fits"));
}

#[test]
fn explore_reports_best_config() {
    let (ok, text) = run(&[&["explore"], SMALL, &["--evaluator", "pms"]].concat());
    assert!(ok, "{text}");
    assert!(text.contains("best:"));
    assert!(text.contains("cache:"));
}

#[test]
fn engine_option_accepts_both_cores_and_they_agree() {
    // simulate under both replay cores: accepted, and the simulated
    // cycle totals must be bit-identical (the engines differ only in
    // execution strategy).
    let lockstep = run(&[&["simulate"], SMALL, &["--rank", "8", "--engine", "lockstep"]].concat());
    let event = run(&[&["simulate"], SMALL, &["--rank", "8", "--engine", "event"]].concat());
    assert!(lockstep.0, "{}", lockstep.1);
    assert!(event.0, "{}", event.1);
    assert!(lockstep.1.contains("engine: lockstep"), "{}", lockstep.1);
    assert!(event.1.contains("engine: event"), "{}", event.1);
    let total_line = |text: &str| -> String {
        text.lines()
            .find(|l| l.starts_with("total cycles:"))
            .expect("total cycles line")
            .to_string()
    };
    assert_eq!(
        total_line(&lockstep.1),
        total_line(&event.1),
        "engines must report identical cycle totals"
    );
}

#[test]
fn grid_engine_simulate_matches_lockstep_totals() {
    // `--engine grid` on a single-trace replay is served by the event
    // kernels — totals must match lockstep exactly.
    let lockstep = run(&[&["simulate"], SMALL, &["--rank", "8", "--engine", "lockstep"]].concat());
    let grid = run(&[&["simulate"], SMALL, &["--rank", "8", "--engine", "grid"]].concat());
    assert!(lockstep.0, "{}", lockstep.1);
    assert!(grid.0, "{}", grid.1);
    assert!(grid.1.contains("engine: grid"), "{}", grid.1);
    let total_line = |text: &str| -> String {
        text.lines()
            .find(|l| l.starts_with("total cycles:"))
            .expect("total cycles line")
            .to_string()
    };
    assert_eq!(total_line(&lockstep.1), total_line(&grid.1));
}

#[test]
fn explore_grid_evaluator_matches_sim_evaluator() {
    // `--evaluator grid` (one-pass cache-module scoring) must pick the
    // same best configuration at the same score as `--evaluator sim`.
    let sim = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "sim", "--rank", "8", "--engine", "event"],
    ]
    .concat());
    let grid = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "grid", "--rank", "8"],
    ]
    .concat());
    assert!(sim.0, "{}", sim.1);
    assert!(grid.0, "{}", grid.1);
    assert!(grid.1.contains("one-pass cache-module scoring"), "{}", grid.1);
    let line = |text: &str, prefix: &str| -> String {
        text.lines()
            .find(|l| l.trim_start().starts_with(prefix))
            .unwrap_or_else(|| panic!("missing {prefix:?} in {text}"))
            .to_string()
    };
    assert_eq!(line(&sim.1, "best:"), line(&grid.1, "best:"));
    assert_eq!(line(&sim.1, "cache:"), line(&grid.1, "cache:"));
}

#[test]
fn grid_evaluator_rejects_conflicting_engine() {
    let (ok, text) = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "grid", "--engine", "lockstep"],
    ]
    .concat());
    assert!(!ok);
    assert!(text.contains("pins --engine grid"), "{text}");
    // An explicit matching --engine grid is fine.
    let (ok, text) = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "grid", "--engine", "grid", "--rank", "4"],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("engine: grid"), "{text}");
}

#[test]
fn explore_sharded_accepts_grid_engine() {
    let (ok, text) = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "sharded", "--workers", "2", "--engine", "grid"],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("engine: grid"), "{text}");
    assert!(text.contains("best:"), "{text}");
}

#[test]
fn explore_search_and_top_k_flags_shape_the_report() {
    let (ok, text) = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "pms", "--search", "joint", "--top-k", "3"],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("search: joint (top-k 3)"), "{text}");
    assert!(text.contains("top-3 points:"), "{text}");
    for i in 1..=3 {
        assert!(text.contains(&format!("  {i}: ")), "missing top entry {i}: {text}");
    }
    assert!(
        text.contains("pareto frontier (cycles vs on-chip blocks vs memory power):"),
        "{text}"
    );
    assert!(text.contains("best:"), "{text}");
    assert!(text.contains("blocks"), "{text}");
}

#[test]
fn explore_defaults_to_coordinate_with_single_winner() {
    let (ok, text) = run(&[&["explore"], SMALL, &["--evaluator", "pms"]].concat());
    assert!(ok, "{text}");
    assert!(text.contains("search: coordinate (top-k 1)"), "{text}");
    // Single-winner report: no top-k section, but the frontier is
    // always there.
    assert!(!text.contains("points:\n  1: "), "{text}");
    assert!(text.contains("pareto frontier"), "{text}");
}

#[test]
fn explore_rejects_unknown_search() {
    let (ok, text) = run(&[&["explore"], SMALL, &["--search", "bogus"]].concat());
    assert!(!ok);
    assert!(text.contains("coordinate|joint|beam"), "{text}");
}

#[test]
fn explore_joint_never_reports_worse_best_than_coordinate() {
    let best_cycles = |text: &str| -> f64 {
        text.lines()
            .find(|l| l.starts_with("best: "))
            .expect("best line")
            .strip_prefix("best: ")
            .unwrap()
            .split(' ')
            .next()
            .unwrap()
            .parse()
            .expect("parse best cycles")
    };
    let coord = run(&[&["explore"], SMALL, &["--evaluator", "pms"]].concat());
    let joint = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "pms", "--search", "joint"],
    ]
    .concat());
    assert!(coord.0, "{}", coord.1);
    assert!(joint.0, "{}", joint.1);
    assert!(
        best_cycles(&joint.1) <= best_cycles(&coord.1),
        "joint best must be <= coordinate best:\n{}\n{}",
        joint.1,
        coord.1
    );
}

#[test]
fn explore_beam_search_runs_and_reports() {
    let (ok, text) = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "pms", "--search", "beam", "--top-k", "2"],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("search: beam (top-k 2)"), "{text}");
    assert!(text.contains("top-2 points:"), "{text}");
    assert!(text.contains("best:"), "{text}");
}

#[test]
fn config_file_dse_section_sets_search_defaults() {
    let dir = std::env::temp_dir().join("ptmc_cli_dse_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("ptmc.toml");
    std::fs::write(&cfg, "[dse]\nsearch = \"joint\"\ntop_k = 2\n").unwrap();
    let (ok, text) = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "pms", "--config", cfg.to_str().unwrap()],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("search: joint (top-k 2)"), "{text}");
    assert!(text.contains("top-2 points:"), "{text}");
    // Explicit flags override the file.
    let (ok, text) = run(&[
        &["explore"],
        SMALL,
        &[
            "--evaluator",
            "pms",
            "--config",
            cfg.to_str().unwrap(),
            "--search",
            "coordinate",
        ],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("search: coordinate (top-k 2)"), "{text}");
}

#[test]
fn row_policy_option_parses_and_is_validated() {
    // The DRAM row-policy knob: accepted values steer the simulator
    // (closed page loses the streaming row hits, so the totals differ),
    // unknown values fail loudly.
    let open = run(&[&["simulate"], SMALL, &["--rank", "8", "--row-policy", "open"]].concat());
    let closed = run(&[&["simulate"], SMALL, &["--rank", "8", "--row-policy", "closed"]].concat());
    assert!(open.0, "{}", open.1);
    assert!(closed.0, "{}", closed.1);
    let total_line = |text: &str| -> String {
        text.lines()
            .find(|l| l.starts_with("total cycles:"))
            .expect("total cycles line")
            .to_string()
    };
    assert_ne!(
        total_line(&open.1),
        total_line(&closed.1),
        "row policy must move the simulated total"
    );
    let (ok, text) = run(&[&["simulate"], SMALL, &["--row-policy", "adaptive"]].concat());
    assert!(!ok);
    assert!(text.contains("row-policy"), "{text}");
    assert!(text.contains("open|closed"), "{text}");
}

#[test]
fn dram_banks_option_is_accepted() {
    let (ok, text) = run(&[&["simulate"], SMALL, &["--rank", "8", "--dram-banks", "8"]].concat());
    assert!(ok, "{text}");
    assert!(text.contains("total cycles:"), "{text}");
}

#[test]
fn help_mentions_dram_timing_knobs() {
    let (ok, text) = run(&["--help"]);
    assert!(ok);
    assert!(text.contains("--row-policy"), "{text}");
    assert!(text.contains("--dram-banks"), "{text}");
    assert!(text.contains("DRAM timing"), "{text}");
    assert!(text.contains("--memory-tech"), "{text}");
    assert!(text.contains("--mem-techs"), "{text}");
}

#[test]
fn memory_tech_option_selects_the_technology() {
    // Each technology is accepted, reported in the config summary, and
    // actually changes the simulated total (the devices time bursts
    // differently by construction).
    let mut totals = Vec::new();
    for tech in ["ddr4", "hbm2", "osram"] {
        let (ok, text) =
            run(&[&["simulate"], SMALL, &["--rank", "8", "--memory-tech", tech]].concat());
        assert!(ok, "{text}");
        assert!(text.contains(tech), "summary must name the tech: {text}");
        let total = text
            .lines()
            .find(|l| l.starts_with("total cycles:"))
            .expect("total cycles line")
            .to_string();
        totals.push(total);
    }
    assert_ne!(totals[0], totals[1], "hbm2 must move the total vs ddr4");
    assert_ne!(totals[0], totals[2], "osram must move the total vs ddr4");
}

#[test]
fn memory_tech_rejects_unknown_and_conflicting_dram_flags() {
    let (ok, text) = run(&[&["simulate"], SMALL, &["--memory-tech", "hbm3"]].concat());
    assert!(!ok);
    assert!(text.contains("ddr4|hbm2|osram"), "{text}");
    // DDR4-shaped flags under a non-DDR4 technology are a clear error,
    // not a silent ignore.
    let (ok, text) = run(&[
        &["simulate"],
        SMALL,
        &["--rank", "8", "--memory-tech", "osram", "--dram-banks", "8"],
    ]
    .concat());
    assert!(!ok);
    assert!(text.contains("--dram-banks"), "{text}");
    assert!(text.contains("osram"), "{text}");
    // The same flags under explicit DDR4 keep working.
    let (ok, text) = run(&[
        &["simulate"],
        SMALL,
        &["--rank", "8", "--memory-tech", "ddr4", "--dram-banks", "8"],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("total cycles:"), "{text}");
}

#[test]
fn explore_mem_techs_all_reports_cross_technology_frontier() {
    // Sweeping all three technologies on an HBM-capable board must
    // produce a frontier and a best point that names its technology
    // and power proxy.
    let (ok, text) = run(&[
        &["explore"],
        SMALL,
        &["--evaluator", "pms", "--search", "joint", "--device", "u280", "--mem-techs", "all"],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("memory:"), "{text}");
    assert!(text.contains("mW"), "{text}");
    assert!(
        text.contains("pareto frontier (cycles vs on-chip blocks vs memory power):"),
        "{text}"
    );
    let (ok, text) = run(&[&["explore"], SMALL, &["--mem-techs", "bogus"]].concat());
    assert!(!ok);
    assert!(text.contains("mem-techs"), "{text}");
}

#[test]
fn engine_option_rejects_unknown_value() {
    let (ok, text) = run(&[&["simulate"], SMALL, &["--engine", "bogus"]].concat());
    assert!(!ok);
    assert!(text.contains("--engine"), "{text}");
    assert!(text.contains("lockstep|event"), "{text}");
}

#[test]
fn explore_sharded_reports_engine_for_both_cores() {
    for engine in ["event", "lockstep"] {
        let (ok, text) = run(&[
            &["explore"],
            SMALL,
            &["--evaluator", "sharded", "--workers", "2", "--engine", engine],
        ]
        .concat());
        assert!(ok, "{text}");
        assert!(text.contains(&format!("engine: {engine}")), "{text}");
        assert!(text.contains("best:"), "{text}");
    }
}

#[test]
fn shard_plan_report_has_expected_shape() {
    let (ok, text) = run(&[&["shard"], SMALL, &["--workers", "3", "--mode", "1"]].concat());
    assert!(ok, "{text}");
    // Header + one imbalance line + one line per shard with ranges,
    // row counts, nnz counts, and percentage shares.
    assert!(text.contains("3 workers"), "{text}");
    assert_eq!(text.matches("imbalance").count(), 1, "{text}");
    assert_eq!(text.matches("coords [").count(), 3, "{text}");
    assert_eq!(text.matches("rows)").count(), 3, "{text}");
    assert_eq!(text.matches("nnz (").count(), 3, "{text}");
    assert_eq!(text.matches('%').count(), 3, "{text}");
    // Shard nnz shares must sum to the workload's nnz.
    let total: usize = text
        .lines()
        .filter(|l| l.contains("nnz ("))
        .map(|l| {
            let before = l.split(" nnz (").next().unwrap();
            before
                .rsplit(' ')
                .next()
                .unwrap()
                .parse::<usize>()
                .expect("nnz count")
        })
        .sum();
    assert_eq!(total, 5000, "{text}");
}

#[test]
fn unknown_flag_fails_loudly() {
    let (ok, text) = run(&["stats", "--bogus", "1"]);
    assert!(!ok);
    assert!(text.contains("--bogus"), "{text}");
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"), "{text}");
}

#[test]
fn config_file_overrides_defaults() {
    let dir = std::env::temp_dir().join("ptmc_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("ptmc.toml");
    std::fs::write(&cfg, "[cache]\nnum_lines = 128\nassoc = 2\n").unwrap();
    let (ok, text) = run(&[
        &["simulate"],
        SMALL,
        &["--config", cfg.to_str().unwrap()],
    ]
    .concat());
    assert!(ok, "{text}");
    // A 128-line cache on this workload must show a sub-90% hit rate
    // (the default 1024-line cache shows >90%).
    assert!(text.contains("cache:"), "{text}");
}

#[test]
fn decompose_pjrt_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let (ok, text) = run(&[
        &["decompose"],
        SMALL,
        &[
            "--rank", "16", "--iters", "1", "--backend", "pjrt", "--seg", "refseg",
            "--tol", "0",
        ],
    ]
    .concat());
    assert!(ok, "{text}");
    assert!(text.contains("coordinator:"), "{text}");
    assert!(text.contains("final fit:"), "{text}");
}

// ---- PR 9: per-error-class exit codes -----------------------------------
//
// Each failure class carries a distinct nonzero exit code so scripts
// and CI can branch on *why* a run failed: 2 usage, 3 parse, 4 I/O,
// 5 budget, 6 worker (1 stays the catch-all).

#[test]
fn usage_errors_exit_with_code_2() {
    let (code, text) = run_code(&["stats", "--bogus", "1"]);
    assert_eq!(code, Some(2), "{text}");
    let (code, text) = run_code(&["frobnicate"]);
    assert_eq!(code, Some(2), "{text}");
    let (code, text) = run_code(&[&["explore"], SMALL, &["--search", "bogus"]].concat());
    assert_eq!(code, Some(2), "{text}");
}

#[test]
fn parse_errors_exit_with_code_3_and_name_the_line() {
    let dir = std::env::temp_dir().join("ptmc_cli_exit_test");
    std::fs::create_dir_all(&dir).unwrap();
    let tns = dir.join("garbage.tns");
    std::fs::write(&tns, "1 1 1 1.0\n2 x 2 2.0\n").unwrap();
    let (code, text) = run_code(&["stats", "--input", tns.to_str().unwrap()]);
    assert_eq!(code, Some(3), "{text}");
    assert!(text.contains("line 2"), "parse error must name the line: {text}");
}

#[test]
fn io_errors_exit_with_code_4() {
    let missing = std::env::temp_dir()
        .join("ptmc_cli_exit_test")
        .join("no_such_file.tns");
    let _ = std::fs::remove_file(&missing);
    let (code, text) = run_code(&["stats", "--input", missing.to_str().unwrap()]);
    assert_eq!(code, Some(4), "{text}");
}

#[test]
fn budget_violations_exit_with_code_5() {
    // 1 KiB is below any process's peak RSS, so the post-run budget
    // check must fail with the Budget class — not a generic error.
    let (code, text) = run_code(&[
        &["decompose"],
        SMALL,
        &[
            "--rank", "4", "--iters", "1", "--backend", "native", "--tol", "0",
            "--memory-budget", "1k",
        ],
    ]
    .concat());
    assert_eq!(code, Some(5), "{text}");
    assert!(text.contains("exceeded --memory-budget"), "{text}");
}

#[test]
fn injected_worker_faults_exit_with_code_6() {
    // A persistent (non-transient) injected panic in a shard worker
    // must surface as the Worker class after supervision retries.
    let out = Command::new(env!("CARGO_BIN_EXE_ptmc"))
        .args(
            [
                &["decompose"],
                SMALL,
                &[
                    "--rank", "4", "--iters", "1", "--backend", "parallel",
                    "--workers", "2", "--tol", "0",
                ],
            ]
            .concat(),
        )
        .env("PTMC_FAULT_PLAN", "shard.worker@1%1:panic")
        .output()
        .expect("spawn ptmc");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(out.status.code(), Some(6), "{text}");
    assert!(text.contains("shard worker"), "{text}");
}

#[test]
fn transient_injected_faults_are_retried_to_success() {
    // One transient fault on the first worker attempt: supervision
    // retries and the run completes normally (exit 0).
    let out = Command::new(env!("CARGO_BIN_EXE_ptmc"))
        .args(
            [
                &["decompose"],
                SMALL,
                &[
                    "--rank", "4", "--iters", "1", "--backend", "parallel",
                    "--workers", "2", "--tol", "0",
                ],
            ]
            .concat(),
        )
        .env("PTMC_FAULT_PLAN", "shard.worker@1:interrupted")
        .output()
        .expect("spawn ptmc");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(out.status.code(), Some(0), "{text}");
    assert!(text.contains("final fit:"), "{text}");
}

#[test]
fn malformed_fault_plans_fail_loudly_at_startup() {
    let out = Command::new(env!("CARGO_BIN_EXE_ptmc"))
        .args([&["stats"], SMALL].concat())
        .env("PTMC_FAULT_PLAN", "no.such.site@1")
        .output()
        .expect("spawn ptmc");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(out.status.code(), Some(2), "{text}");
    assert!(text.contains("PTMC_FAULT_PLAN"), "{text}");
    assert!(text.contains("no.such.site"), "{text}");
}

#[test]
fn explore_checkpoint_every_is_accepted_and_warns_without_cache() {
    let dir = std::env::temp_dir().join("ptmc_cli_ckpt_test");
    let _ = std::fs::remove_dir_all(&dir);
    // With a warm cache: accepted, run succeeds, cache directory is
    // populated by the periodic + final flushes.
    let (code, text) = run_code(&[
        &["explore"],
        SMALL,
        &[
            "--evaluator", "pms", "--warm-cache", dir.to_str().unwrap(),
            "--checkpoint-every", "2",
        ],
    ]
    .concat());
    assert_eq!(code, Some(0), "{text}");
    assert!(text.contains("best:"), "{text}");
    assert!(dir.exists(), "warm cache dir must exist after explore: {text}");
    // Without a cache the flag is inert — say so, but do not fail.
    let (code, text) = run_code(&[
        &["explore"],
        SMALL,
        &["--evaluator", "pms", "--checkpoint-every", "2"],
    ]
    .concat());
    assert_eq!(code, Some(0), "{text}");
    assert!(text.contains("no effect without --warm-cache"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn help_documents_robustness_flags() {
    let (ok, text) = run(&["--help"]);
    assert!(ok);
    assert!(text.contains("--checkpoint-every"), "{text}");
    assert!(text.contains("--warm-cache"), "{text}");
}
