//! Differential test harness for the simulation engines: on a seeded
//! corpus of synthetic tensors (varying mode counts, nnz, and Zipf
//! skew) and a small grid of controller configurations, the event
//! engine, the lockstep engine, and the grid core (stack-distance
//! classification + miss-only replay, `ptmc::engine::grid`) must
//! produce **identical** completion cycles and statistics —
//! `ControllerStats`, `CacheStats`, `DmaStats`, and DRAM stats
//! including row activations.  The compressed trace must also be a
//! lossless encoding of the raw trace.

use ptmc::controller::{
    Access, CacheConfig, ControllerConfig, DmaConfig, MemLayout, MemoryController,
};
use ptmc::dram::RowPolicy;
use ptmc::engine::{
    ClassifyKernel, CompressedTrace, EngineKind, GridClassification, JointIndex, PreparedTrace,
    SimEngine, TimingCandidate, TimingOps,
};
use ptmc::mttkrp::{approach1, Tracing};
use ptmc::shard::{partition_indices, shard_trace, ShardPlan, ShardedSweep};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::tensor::SparseTensor;
use ptmc::testkit::{forall, Rng};

/// A random synthetic tensor: 3 or 4 modes, varying nnz and skew.
fn random_tensor(rng: &mut Rng) -> SparseTensor {
    let n_modes = rng.range(3, 5);
    let dims: Vec<usize> = (0..n_modes).map(|_| rng.range(30, 300)).collect();
    let space: usize = dims.iter().product();
    let nnz = rng.range(1, 2_000).min(space / 4).max(1);
    let profile = match rng.below(3) {
        0 => Profile::Uniform,
        1 => Profile::Zipf {
            alpha_milli: 1_050 + rng.below(500) as u32,
        },
        _ => Profile::Clustered {
            block: 8,
            blocks: 20,
        },
    };
    generate(&SynthConfig {
        dims,
        nnz,
        profile,
        seed: rng.next_u64(),
    })
}

/// The small configuration grid every trace is replayed under.
fn config_grid(elem_bytes: usize) -> Vec<ControllerConfig> {
    let mut grid = Vec::new();
    for (num_lines, assoc) in [(64usize, 1usize), (1024, 4)] {
        for (num_dmas, buffer_bytes) in [(1usize, 1024usize), (2, 4096)] {
            let mut cfg = ControllerConfig::default_for(elem_bytes);
            cfg.cache = CacheConfig {
                line_bytes: 64,
                num_lines,
                assoc,
                hit_latency: 2,
            };
            cfg.dma = DmaConfig {
                num_dmas,
                buffers_per_dma: 2,
                buffer_bytes,
                setup_cycles: 8,
            };
            grid.push(cfg);
        }
    }
    grid
}

/// Replay `prepared` under both engines on fresh controllers of `cfg`;
/// assert completion cycle and every counter match bit-for-bit.
fn assert_engines_identical(prepared: &PreparedTrace, cfg: &ControllerConfig, what: &str) {
    let mut lockstep = MemoryController::new(cfg.clone());
    let mut event = MemoryController::new(cfg.clone());
    let tl = EngineKind::Lockstep.replay(&mut lockstep, prepared);
    let te = EngineKind::Event.replay(&mut event, prepared);
    assert_eq!(tl, te, "{what}: completion cycles diverged");
    assert_eq!(lockstep.now(), event.now(), "{what}: clocks diverged");
    assert_eq!(
        lockstep.stats(),
        event.stats(),
        "{what}: ControllerStats diverged"
    );
    assert_eq!(
        lockstep.cache_stats(),
        event.cache_stats(),
        "{what}: CacheStats diverged"
    );
    assert_eq!(
        lockstep.dma_stats(),
        event.dma_stats(),
        "{what}: DmaStats diverged"
    );
    assert_eq!(
        lockstep.dram_stats(),
        event.dram_stats(),
        "{what}: DramStats diverged"
    );
    assert_eq!(
        lockstep.dram_stats().activations(),
        event.dram_stats().activations(),
        "{what}: row activations diverged"
    );

    // The grid core: classify this configuration's cache alone, then
    // time it with the miss-only replay — cycle count and every counter
    // must match the lockstep controller bit-for-bit.
    let cls = GridClassification::classify(prepared.compressed(), &[cfg.cache]);
    let run = cls.replay(0, prepared.compressed(), cfg);
    assert_eq!(run.cycles, tl, "{what}: grid-core cycles diverged");
    assert_eq!(
        run.stats,
        *lockstep.stats(),
        "{what}: grid ControllerStats diverged"
    );
    assert_eq!(
        run.cache,
        *lockstep.cache_stats(),
        "{what}: grid CacheStats diverged"
    );
    assert_eq!(
        run.dma,
        *lockstep.dma_stats(),
        "{what}: grid DmaStats diverged"
    );
    assert_eq!(
        run.dram,
        *lockstep.dram_stats(),
        "{what}: grid DramStats diverged"
    );

    // The scalar classification kernel is the SoA kernel's oracle
    // (S28): the default `classify` above ran SoA, so re-classifying
    // with the scalar kernel must reproduce the identical statistics
    // and the identical miss-only replay, bit for bit.
    let scalar = GridClassification::classify_with(
        prepared.compressed(),
        &[cfg.cache],
        ClassifyKernel::Scalar,
    );
    assert_eq!(
        scalar.cache_stats(0),
        cls.cache_stats(0),
        "{what}: scalar/SoA kernel stats diverged"
    );
    assert_eq!(
        scalar.replay(0, prepared.compressed(), cfg),
        run,
        "{what}: scalar/SoA kernel replay diverged"
    );

    // The timing-grid column: extract the configuration's miss/stream
    // op queue from the same classification and time it as a one-lane
    // grid — cycles and every counter must match the lockstep
    // controller bit-for-bit too.
    let ops = TimingOps::extract(&cls, 0, prepared.compressed());
    let truns = ops.time_grid(&[TimingCandidate::of(cfg)]);
    assert_eq!(truns.len(), 1);
    assert_eq!(truns[0].cycles, tl, "{what}: timing-core cycles diverged");
    assert_eq!(
        truns[0].stats,
        *lockstep.stats(),
        "{what}: timing ControllerStats diverged"
    );
    assert_eq!(
        truns[0].cache,
        *lockstep.cache_stats(),
        "{what}: timing CacheStats diverged"
    );
    assert_eq!(
        truns[0].dma,
        *lockstep.dma_stats(),
        "{what}: timing DmaStats diverged"
    );
    assert_eq!(
        truns[0].dram,
        *lockstep.dram_stats(),
        "{what}: timing DramStats diverged"
    );

    // The joint-grid column: the same configuration as a one-cell
    // hierarchical joint sweep (classify → extract → lane walk) must
    // complete at the identical cycle.
    let jidx = JointIndex::build(&[(cfg.cache, TimingCandidate::of(cfg))]);
    assert_eq!(
        jidx.sweep(prepared.compressed()),
        vec![tl],
        "{what}: joint-core cycles diverged"
    );
}

#[test]
fn event_engine_is_bit_identical_on_shard_traces() {
    forall("event_vs_lockstep_shard_traces", 12, |rng| {
        let t = random_tensor(rng);
        let rank = [4usize, 8, 16][rng.range(0, 3)];
        let mode = rng.range(0, t.n_modes());
        let workers = rng.range(1, 5);
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let plan = ShardPlan::balance(&t, mode, workers);
        let parts = partition_indices(&t, &plan);
        let mut offset = 0usize;
        for (spec, zs) in plan.shards.iter().zip(&parts) {
            let trace = shard_trace(&t, rank, mode, &layout, spec, zs, offset);
            offset += spec.nnz;
            let prepared = PreparedTrace::new(trace.clone());
            assert_eq!(
                prepared.compressed().expand(),
                trace,
                "compress/expand must be lossless"
            );
            for cfg in config_grid(t.record_bytes()) {
                assert_engines_identical(&prepared, &cfg, "shard trace");
            }
        }
    });
}

#[test]
fn event_engine_is_bit_identical_on_approach1_traces() {
    forall("event_vs_lockstep_approach1", 8, |rng| {
        let t = random_tensor(rng);
        let rank = [4usize, 8][rng.range(0, 2)];
        let mode = rng.range(0, t.n_modes());
        let factors: Vec<_> = t
            .dims()
            .iter()
            .map(|&d| ptmc::cpd::linalg::Mat::randn(d, rank, rng.next_u64()))
            .collect();
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let mut t = t;
        t.sort_by_mode(mode);
        let run = approach1::run(&t, &factors, mode, &layout, Tracing::On);
        let prepared = PreparedTrace::new(run.trace);
        for cfg in config_grid(t.record_bytes()) {
            assert_engines_identical(&prepared, &cfg, "approach1 trace");
        }
    });
}

#[test]
fn event_engine_is_bit_identical_on_adversarial_access_mixes() {
    // Cold classes (Element / CachedStore), width changes mid-run,
    // unaligned addresses, and far-apart cached addresses all exercise
    // the compressor's fallback paths.
    forall("event_vs_lockstep_adversarial", 16, |rng| {
        let n = rng.range(1, 600);
        let mut trace = Vec::with_capacity(n);
        for i in 0..n as u64 {
            let a = match rng.below(8) {
                0 => Access::Stream {
                    addr: i * 4096,
                    bytes: 4096,
                },
                1 => Access::Stream {
                    addr: rng.below(1 << 30),
                    bytes: 1 + rng.below(8192) as usize,
                },
                2 => Access::Cached {
                    addr: (8 << 20) + rng.below(1 << 14) * 64,
                    bytes: 64,
                },
                3 => Access::Cached {
                    // Unaligned and variable width.
                    addr: rng.below(1 << 26),
                    bytes: 1 + rng.below(256) as usize,
                },
                4 => Access::Cached {
                    // Far beyond the u32 delta window.
                    addr: (1 << 40) + rng.below(1 << 20) * 64,
                    bytes: 64,
                },
                5 => Access::Element {
                    addr: rng.below(1 << 32),
                    bytes: 16,
                },
                6 => Access::CachedStore {
                    addr: rng.below(1 << 24) * 16,
                    bytes: 16,
                },
                _ => Access::Stream {
                    addr: (2 << 30) + (i % 7) * 64,
                    bytes: 64,
                },
            };
            trace.push(a);
        }
        let prepared = PreparedTrace::new(trace.clone());
        assert_eq!(prepared.compressed().expand(), trace);
        assert_eq!(
            CompressedTrace::compress(&trace).len(),
            trace.len(),
            "request count must be preserved"
        );
        for cfg in config_grid(16) {
            assert_engines_identical(&prepared, &cfg, "adversarial trace");
        }
    });
}

/// The cache grid the batch-classification tests score at once.
fn cache_grid() -> Vec<CacheConfig> {
    let mut grid = Vec::new();
    for &line_bytes in &[32usize, 64, 128] {
        for &(num_lines, assoc) in &[(64usize, 1usize), (256, 2), (1024, 4), (4096, 8)] {
            grid.push(CacheConfig {
                line_bytes,
                num_lines,
                assoc,
                hit_latency: 2,
            });
        }
    }
    grid
}

#[test]
fn grid_core_scores_whole_cache_grid_bit_identically() {
    // One classification pass, twelve candidates: every candidate's
    // miss-only replay must equal a dedicated lockstep controller run
    // in cycles and all statistics.
    forall("grid_batch_vs_lockstep", 8, |rng| {
        let t = random_tensor(rng);
        let rank = [4usize, 8, 16][rng.range(0, 3)];
        let mode = rng.range(0, t.n_modes());
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let plan = ShardPlan::balance(&t, mode, 2);
        let parts = partition_indices(&t, &plan);
        let trace = shard_trace(&t, rank, mode, &layout, &plan.shards[0], &parts[0], 0);
        let prepared = PreparedTrace::new(trace);
        let grid = cache_grid();
        let cls = GridClassification::classify(prepared.compressed(), &grid);
        for (ci, cc) in grid.iter().enumerate() {
            let mut cfg = ControllerConfig::default_for(t.record_bytes());
            cfg.cache = *cc;
            let mut ctl = MemoryController::new(cfg.clone());
            let want = EngineKind::Lockstep.replay(&mut ctl, &prepared);
            let run = cls.replay(ci, prepared.compressed(), &cfg);
            assert_eq!(run.cycles, want, "candidate {cc:?}");
            assert_eq!(run.cache, *ctl.cache_stats(), "candidate {cc:?}");
            assert_eq!(run.dram, *ctl.dram_stats(), "candidate {cc:?}");
            assert_eq!(run.dma, *ctl.dma_stats(), "candidate {cc:?}");
            assert_eq!(run.stats, *ctl.stats(), "candidate {cc:?}");
        }
    });
}

#[test]
fn sharded_sweep_cache_grid_matches_per_candidate_makespans() {
    // The full one-pass DSE path: per-shard grid classification +
    // memoized remap must reproduce the event/lockstep makespan of
    // every candidate exactly.
    forall("sweep_cache_grid_vs_event", 5, |rng| {
        let t = random_tensor(rng);
        let workers = rng.range(1, 4);
        let sweep = ShardedSweep::prepare(&t, 8, workers);
        let base = ControllerConfig::default_for(t.record_bytes());
        let caches: Vec<CacheConfig> = cache_grid().into_iter().take(6).collect();
        let grid_scores = sweep.makespans_for_cache_grid(&base, &caches);
        for (cc, &got) in caches.iter().zip(&grid_scores) {
            let mut cfg = base.clone();
            cfg.cache = *cc;
            assert_eq!(got, sweep.makespan_with(&cfg, EngineKind::Event));
            assert_eq!(got, sweep.makespan_with(&cfg, EngineKind::Lockstep));
        }
    });
}

#[test]
fn sharded_sweep_timing_grid_matches_per_candidate_makespans() {
    // The one-walk DRAM/DMA DSE path: per-shard classification +
    // op-queue extraction + multi-lane timing must reproduce the
    // event/lockstep makespan of every timing candidate exactly,
    // including candidates whose channel count splits differently
    // across workers and closed-row-policy candidates.
    forall("sweep_timing_grid_vs_event", 4, |rng| {
        let t = random_tensor(rng);
        let workers = rng.range(1, 4);
        let sweep = ShardedSweep::prepare(&t, 8, workers);
        let base = ControllerConfig::default_for(t.record_bytes());
        let mut cands = Vec::new();
        for &(channels, banks, policy) in &[
            (1usize, 16usize, RowPolicy::Open),
            (4, 8, RowPolicy::Open),
            (2, 16, RowPolicy::Closed),
        ] {
            for &(num_dmas, buffer_bytes) in &[(1usize, 1024usize), (2, 4096)] {
                let mut cfg = base.clone();
                {
                    let dram = cfg.mem.ddr4_mut();
                    dram.channels = channels;
                    dram.banks = banks;
                    dram.row_policy = policy;
                }
                cfg.dma.num_dmas = num_dmas;
                cfg.dma.buffer_bytes = buffer_bytes;
                cands.push(cfg);
            }
        }
        let got = sweep.makespans_for_timing_grid(&base, &cands);
        for (cfg, &score) in cands.iter().zip(&got) {
            assert_eq!(
                score,
                sweep.makespan_with(cfg, EngineKind::Event),
                "timing-grid makespan diverged from event"
            );
            assert_eq!(
                score,
                sweep.makespan_with(cfg, EngineKind::Lockstep),
                "timing-grid makespan diverged from lockstep"
            );
        }
    });
}

#[test]
fn joint_sweep_core_scores_cross_products_bit_identically() {
    // The hierarchical joint core over a full cache x DRAM x DMA cross
    // product: every joint point's cycle count must equal a dedicated
    // lockstep controller run on the same trace.
    forall("joint_cross_product_vs_lockstep", 5, |rng| {
        let t = random_tensor(rng);
        let rank = [4usize, 8][rng.range(0, 2)];
        let mode = rng.range(0, t.n_modes());
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let plan = ShardPlan::balance(&t, mode, 2);
        let parts = partition_indices(&t, &plan);
        let trace = shard_trace(&t, rank, mode, &layout, &plan.shards[0], &parts[0], 0);
        let prepared = PreparedTrace::new(trace);
        let base = ControllerConfig::default_for(t.record_bytes());
        let mut cfgs = Vec::new();
        for cc in cache_grid().into_iter().take(4) {
            for &(channels, policy, num_dmas) in &[
                (1usize, RowPolicy::Open, 2usize),
                (4, RowPolicy::Closed, 1),
            ] {
                let mut cfg = base.clone();
                cfg.cache = cc;
                {
                    let dram = cfg.mem.ddr4_mut();
                    dram.channels = channels;
                    dram.row_policy = policy;
                }
                cfg.dma.num_dmas = num_dmas;
                cfgs.push(cfg);
            }
        }
        let pairs: Vec<_> = cfgs
            .iter()
            .map(|c| (c.cache, TimingCandidate::of(c)))
            .collect();
        let index = JointIndex::build(&pairs);
        let got = index.sweep(prepared.compressed());
        for (cfg, &cycles) in cfgs.iter().zip(&got) {
            let mut ctl = MemoryController::new(cfg.clone());
            let want = EngineKind::Lockstep.replay(&mut ctl, &prepared);
            assert_eq!(
                cycles, want,
                "joint point diverged: {:?}/{:?}",
                cfg.cache, cfg.mem
            );
        }
    });
}

#[test]
fn sharded_sweep_makespans_agree_across_engines() {
    // The full DSE scoring path: remap memoization and concurrent
    // shard replay on the event side must not change the score.
    forall("sweep_makespan_engines_agree", 6, |rng| {
        let t = random_tensor(rng);
        let workers = rng.range(1, 5);
        let sweep = ShardedSweep::prepare(&t, 8, workers);
        for cfg in config_grid(t.record_bytes()).into_iter().take(2) {
            let lockstep = sweep.makespan_with(&cfg, EngineKind::Lockstep);
            let event = sweep.makespan_with(&cfg, EngineKind::Event);
            assert_eq!(lockstep, event, "sweep makespan diverged");
            // A single-config grid makespan is served by the event
            // kernels — same number by construction.
            assert_eq!(event, sweep.makespan_with(&cfg, EngineKind::Grid));
            // Scoring twice must be deterministic (memo hit path).
            assert_eq!(event, sweep.makespan_with(&cfg, EngineKind::Event));
        }
    });
}

#[test]
fn engine_trait_objects_replay_identically() {
    // The SimEngine trait surface itself: both engines behind dyn
    // references, driven the same way.
    let t = generate(&SynthConfig {
        dims: vec![200, 150, 100],
        nnz: 3_000,
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed: 77,
    });
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 16);
    let plan = ShardPlan::balance(&t, 0, 2);
    let parts = partition_indices(&t, &plan);
    let trace = shard_trace(&t, 16, 0, &layout, &plan.shards[0], &parts[0], 0);
    let prepared = PreparedTrace::new(trace);
    let cfg = ControllerConfig::default_for(t.record_bytes());
    let engines: [&dyn SimEngine; 2] = [
        EngineKind::Lockstep.engine(),
        EngineKind::Event.engine(),
    ];
    let results: Vec<u64> = engines
        .iter()
        .map(|e| {
            let mut ctl = MemoryController::new(cfg.clone());
            e.replay(&mut ctl, &prepared)
        })
        .collect();
    assert_eq!(results[0], results[1]);
    assert_eq!(engines[0].name(), "lockstep");
    assert_eq!(engines[1].name(), "event");
}
