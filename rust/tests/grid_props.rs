//! Property tests for the grid classifier (`ptmc::engine::grid`): on
//! random cache-class traces, the single-pass stack-distance
//! classification must report, for **every** `(line_bytes, num_lines,
//! assoc)` combination in `Grids::default()`, exactly the hit/miss/
//! eviction/writeback counts a fresh `CacheEngine` replay of the same
//! trace produces — Mattson inclusion made executable.

use ptmc::controller::{Access, CacheConfig, CacheEngine};
use ptmc::dram::{Dram, DramConfig};
use ptmc::dse::Grids;
use ptmc::engine::{ClassifyKernel, CompressedTrace, GridClassification};
use ptmc::testkit::{forall, Rng};

/// Every valid cache candidate of the default DSE grid (the same
/// power-of-two-sets filter `dse::explore` applies).
fn default_grid_configs() -> Vec<CacheConfig> {
    let g = Grids::default();
    let mut configs = Vec::new();
    for &line_bytes in &g.cache_line_bytes {
        for &num_lines in &g.cache_num_lines {
            for &assoc in &g.cache_assoc {
                if num_lines % assoc != 0 || !(num_lines / assoc).is_power_of_two() {
                    continue;
                }
                configs.push(CacheConfig {
                    line_bytes,
                    num_lines,
                    assoc,
                    hit_latency: 2,
                });
            }
        }
    }
    configs
}

/// A random cache-class trace: loads and stores, hot zipf rows plus
/// cold uniform addresses, mixed widths, occasional line-straddling and
/// unaligned accesses.
fn random_cache_trace(rng: &mut Rng) -> Vec<Access> {
    let n = rng.range(50, 1_500);
    let mut trace = Vec::with_capacity(n);
    for _ in 0..n {
        let addr = match rng.below(4) {
            0 => rng.zipf(4096, 1.2) * 64,          // hot rows
            1 => rng.below(1 << 22),                 // cold, unaligned
            2 => (8 << 20) + rng.below(1 << 10) * 256, // small working set
            _ => rng.below(1 << 16) * 64,            // medium working set
        };
        let bytes = match rng.below(4) {
            0 => 16,
            1 => 64,
            2 => 1 + rng.below(300) as usize, // straddles lines
            _ => 4,
        };
        if rng.below(4) == 0 {
            trace.push(Access::CachedStore { addr, bytes });
        } else {
            trace.push(Access::Cached { addr, bytes });
        }
    }
    trace
}

/// Replay the cache-class trace through a real `CacheEngine`.
fn engine_replay(trace: &[Access], cfg: CacheConfig) -> ptmc::controller::CacheStats {
    let mut dram = Dram::new(DramConfig::default_ddr4());
    let mut cache = CacheEngine::new(cfg);
    let mut t = 0u64;
    for a in trace {
        t = match *a {
            Access::Cached { addr, bytes } => cache.load(&mut dram, addr, bytes, t),
            Access::CachedStore { addr, bytes } => cache.store(&mut dram, addr, bytes, t),
            _ => t,
        };
    }
    cache.stats().clone()
}

#[test]
fn classifier_matches_cache_engine_on_the_default_grid() {
    let configs = default_grid_configs();
    assert!(
        configs.len() >= 32,
        "the default grid should contribute plenty of candidates"
    );
    forall("grid_classifier_vs_cache_engine", 10, |rng| {
        let trace = random_cache_trace(rng);
        let ct = CompressedTrace::compress(&trace);
        let cls = GridClassification::classify(&ct, &configs);
        for (i, cfg) in configs.iter().enumerate() {
            let want = engine_replay(&trace, *cfg);
            assert_eq!(
                cls.cache_stats(i),
                want,
                "classifier diverged from CacheEngine for {cfg:?}"
            );
            assert_eq!(cls.hits(i), want.hits, "{cfg:?}");
            assert_eq!(cls.misses(i), want.misses, "{cfg:?}");
            assert_eq!(cls.accesses(i), want.accesses, "{cfg:?}");
        }
    });
}

#[test]
fn both_kernels_match_the_cache_engine_on_the_default_grid() {
    // The default entry points run the SoA kernel (S28); the scalar
    // kernel is its oracle.  Both must agree with a real `CacheEngine`
    // replay — and therefore with each other — for every candidate.
    let configs = default_grid_configs();
    forall("grid_kernels_vs_cache_engine", 6, |rng| {
        let trace = random_cache_trace(rng);
        let ct = CompressedTrace::compress(&trace);
        let scalar = GridClassification::classify_with(&ct, &configs, ClassifyKernel::Scalar);
        let soa = GridClassification::classify_with(&ct, &configs, ClassifyKernel::Soa);
        for (i, cfg) in configs.iter().enumerate() {
            let want = engine_replay(&trace, *cfg);
            assert_eq!(scalar.cache_stats(i), want, "scalar vs engine: {cfg:?}");
            assert_eq!(soa.cache_stats(i), want, "soa vs engine: {cfg:?}");
        }
    });
}

#[test]
fn classifier_obeys_mattson_inclusion_across_the_grid() {
    // At a fixed line width and set count, hits are monotone in
    // associativity; at fixed width and associativity, monotone in the
    // number of lines.  (These orderings are what makes the one-pass
    // classification possible at all, so pin them as properties.)
    forall("grid_classifier_inclusion", 8, |rng| {
        let trace = random_cache_trace(rng);
        let ct = CompressedTrace::compress(&trace);

        let assoc_chain: Vec<CacheConfig> = [1usize, 2, 4, 8]
            .iter()
            .map(|&assoc| CacheConfig {
                line_bytes: 64,
                num_lines: 256 * assoc,
                assoc,
                hit_latency: 2,
            })
            .collect();
        let cls = GridClassification::classify(&ct, &assoc_chain);
        for i in 1..assoc_chain.len() {
            assert!(
                cls.hits(i) >= cls.hits(i - 1),
                "hits must grow with ways at fixed sets"
            );
        }

        let size_chain: Vec<CacheConfig> = [256usize, 1024, 4096, 16384]
            .iter()
            .map(|&num_lines| CacheConfig {
                line_bytes: 64,
                num_lines,
                assoc: 4,
                hit_latency: 2,
            })
            .collect();
        let cls = GridClassification::classify(&ct, &size_chain);
        for i in 1..size_chain.len() {
            assert!(
                cls.hits(i) >= cls.hits(i - 1),
                "hits must grow with capacity at fixed assoc"
            );
        }
    });
}

#[test]
fn store_dirty_state_tracks_per_candidate() {
    // A dirty line evicted from a small cache but resident in a large
    // one must write back only for the small candidate.
    let small = CacheConfig {
        line_bytes: 64,
        num_lines: 2,
        assoc: 2,
        hit_latency: 1,
    };
    let large = CacheConfig {
        line_bytes: 64,
        num_lines: 8,
        assoc: 8,
        hit_latency: 1,
    };
    let trace = vec![
        Access::CachedStore { addr: 0, bytes: 16 }, // dirty A
        Access::Cached { addr: 64, bytes: 16 },     // B
        Access::Cached { addr: 128, bytes: 16 },    // C evicts A in `small`
        Access::Cached { addr: 0, bytes: 16 },      // A: miss small, hit large
    ];
    let ct = CompressedTrace::compress(&trace);
    let cls = GridClassification::classify(&ct, &[small, large]);
    assert_eq!(cls.cache_stats(0), engine_replay(&trace, small));
    assert_eq!(cls.cache_stats(1), engine_replay(&trace, large));
    assert_eq!(cls.cache_stats(0).writebacks, 1, "small cache writes A back");
    assert_eq!(cls.cache_stats(1).writebacks, 0, "large cache keeps A dirty");
}
